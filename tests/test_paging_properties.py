"""Property-based fuzz of the prefix-sharing block allocator.

Random interleavings of alloc/extend/share/commit/CoW/release (with
allocation-pressure eviction happening implicitly inside the allocator)
must preserve, after every single operation:

* conservation — ``free_blocks + blocks_in_use == usable_blocks``, and
  every usable block sits in exactly one of {plain free list, cached LRU,
  some chain(s)};
* refcount consistency — a block appears in ``k`` live chains iff its
  refcount is ``k``;
* null-block immutability — block 0 is never handed out, never enters a
  chain, the free pool, or the prefix index.

Runs under real ``hypothesis`` when installed (derandomized, so CI is
reproducible) and under ``tests/_hypothesis_shim.py`` otherwise — either
way the op programs are generated from drawn integer seeds, so coverage is
identical and deterministic across environments.
"""
import random
from collections import Counter

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from repro.serve.paging import NULL_BLOCK, BlockAllocator


VOCAB = 3          # tiny vocab => frequent prefix collisions in the index


def check_invariants(a: BlockAllocator, live):
    """``live`` is the reference {rid: [blocks]} mirror built from the
    allocator's own return values."""
    # the allocator's chains match the mirror exactly
    assert set(a._chains) == set(live)
    for rid, chain in live.items():
        assert a.chain(rid) == tuple(chain), rid
    # refcount consistency: in k chains <=> refcount k
    counts = Counter(b for chain in live.values() for b in chain)
    for blk in range(1, a.num_blocks):
        assert a.refcount(blk) == counts.get(blk, 0), blk
    for chain in live.values():                      # at most once per chain
        assert len(chain) == len(set(chain))
    # conservation: free list, cached LRU, and in-use chains partition the
    # usable blocks
    free, cached = set(a._free), set(a._cached)
    in_use = set(counts)
    assert not free & cached
    assert not free & in_use
    assert not cached & in_use
    assert free | cached | in_use == set(range(1, a.num_blocks))
    assert a.free_blocks == len(free) + len(cached)
    assert a.free_blocks + a.blocks_in_use == a.usable_blocks
    # null-block immutability
    assert NULL_BLOCK not in counts
    assert NULL_BLOCK not in free and NULL_BLOCK not in cached
    assert a.refcount(NULL_BLOCK) == 0
    assert NULL_BLOCK not in a._by_block
    # every cached-LRU block's refcount is 0 (eviction only touches dead
    # blocks) and every indexed block is a real block
    for blk in cached:
        assert a.refcount(blk) == 0
    for blk in a._by_block:
        assert 1 <= blk < a.num_blocks


def run_program(seed: int, *, n_ops: int = 60) -> BlockAllocator:
    rng = random.Random(seed)
    num_blocks = rng.randint(4, 20)
    bs = rng.choice([1, 2, 4])
    a = BlockAllocator(num_blocks, bs, prefix_cache=True)
    tok_rng = np.random.default_rng(seed)
    live = {}          # rid -> expected chain
    toks = {}          # rid -> token sequence backing the chain
    next_rid = 0

    for _ in range(n_ops):
        op = rng.choice(["alloc", "alloc", "extend", "commit", "commit",
                         "cow", "release"])
        if op == "alloc":
            rid = next_rid
            next_rid += 1
            n_tok = rng.randint(0, (num_blocks + 1) * bs)
            seq = tok_rng.integers(0, VOCAB, (n_tok,)).astype(np.int32)
            shared = a.match_prefix(seq)
            assert NULL_BLOCK not in shared
            n_fresh = rng.randint(0, 3)
            chain = a.alloc_chain(rid, n_fresh, shared=shared)
            if chain is None:
                assert not a.can_allocate(n_fresh, shared)
            else:
                assert len(chain) == len(shared) + n_fresh
                assert chain[:len(shared)] == shared
                assert NULL_BLOCK not in chain
                live[rid] = chain
                toks[rid] = seq
        elif op == "extend" and live:
            rid = rng.choice(sorted(live))
            blk = a.extend(rid)
            if blk is None:
                assert a.free_blocks == 0
            else:
                assert blk != NULL_BLOCK
                live[rid].append(blk)
                toks[rid] = np.concatenate(
                    [toks[rid],
                     tok_rng.integers(0, VOCAB, (bs,)).astype(np.int32)])
        elif op == "commit" and live:
            rid = rng.choice(sorted(live))
            k = rng.randint(0, len(toks[rid]))
            a.commit_prefix(rid, toks[rid][:k])
        elif op == "cow" and live:
            rid = rng.choice(sorted(live))
            if live[rid]:
                j = rng.randrange(len(live[rid]))
                res = a.cow(rid, j)
                if res is None:
                    assert a.free_blocks == 0
                else:
                    old, new = res
                    assert old == live[rid][j]
                    assert new != NULL_BLOCK and new != old
                    live[rid][j] = new
        elif op == "release" and live:
            rid = rng.choice(sorted(live))
            held_elsewhere = {b for r2, c in live.items() if r2 != rid
                              for b in c}
            freed = a.release(rid)
            assert freed == sum(1 for b in live[rid]
                                if b not in held_elsewhere)
            del live[rid]
            del toks[rid]
        check_invariants(a, live)

    # drain: releasing everything returns the pool to fully free
    for rid in sorted(live):
        a.release(rid)
        del live[rid]
        check_invariants(a, live)
    assert a.blocks_in_use == 0
    assert a.free_blocks == a.usable_blocks
    return a


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(0, 2 ** 31 - 1))
def test_allocator_random_interleavings(seed):
    run_program(seed)


def test_allocator_eviction_recycles_cached_prefixes():
    """Filling the pool after a release forces LRU eviction of retained
    (indexed, refcount-0) blocks, deepest-first, and the evicted prefixes
    stop matching."""
    a = BlockAllocator(5, 2, prefix_cache=True)       # 4 usable
    seq = np.array([1, 1, 2, 2, 1, 2], np.int32)
    chain = a.alloc_chain(0, 3)
    a.commit_prefix(0, seq)
    a.release(0)
    assert a.cached_blocks == 3                       # retained, not freed
    assert a.match_prefix(seq) == chain
    # one fresh alloc fits without eviction (one plain-free block)
    b = a.alloc_chain(1, 1)
    assert a.evictions == 0
    # the next must evict: tail blocks (deepest prefix) go first
    c = a.alloc_chain(2, 2)
    assert a.evictions == 2
    assert set(c) == set(chain[1:])                   # recycled tail blocks
    assert a.match_prefix(seq) == chain[:1]           # root still matches
    a.release(1)
    a.release(2)
    assert a.free_blocks == a.usable_blocks


def test_allocator_cow_preserves_shared_chain():
    """CoW swaps a private copy into one chain only; the other holder and
    the index keep the original block."""
    a = BlockAllocator(6, 2, prefix_cache=True)
    seq = np.array([0, 1, 0, 2], np.int32)
    c0 = a.alloc_chain(0, 2)
    a.commit_prefix(0, seq)
    shared = a.match_prefix(seq)
    assert shared == c0
    c1 = a.alloc_chain(1, 0, shared=shared)
    assert a.refcount(c0[0]) == 2
    old, new = a.cow(1, 1)
    assert old == c0[1] and new not in c0
    assert a.chain(0) == tuple(c0)                    # untouched
    assert a.chain(1) == (c0[0], new)
    assert a.refcount(old) == 1 and a.refcount(new) == 1
    assert a.match_prefix(seq) == c0                  # index keeps original
    assert a.cow_copies == 1


def test_allocator_rejects_null_in_shared():
    a = BlockAllocator(4, 2, prefix_cache=True)
    with pytest.raises(ValueError, match="null block"):
        a.alloc_chain(0, 1, shared=[NULL_BLOCK])
