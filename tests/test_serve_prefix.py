"""Prefix-sharing KV cache tests: cache hits skip prefill, streams stay
token-exact with sharing on vs off (including under preemption, LRU
eviction, and EOS at a block boundary), shared blocks survive a holder's
preemption, copy-on-write fires on full-prompt hits and on shared decode
write targets, and the jit caches stay at one entry each."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import build_model
from repro.serve import (EngineConfig, Request, ServeEngine, VirtualClock,
                         engine_config_for, poisson_requests)

from _serve_helpers import captured_run

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _model(cfg, batch, seq_len):
    m = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                    batch=batch, seq_len=seq_len)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(model, params, *, slots, prompt_len, max_new, chunk, **kw):
    ecfg = engine_config_for(model.cfg, max_slots=slots,
                             prompt_len=prompt_len, max_new_tokens=max_new,
                             prefill_chunk=chunk, paged=True,
                             kv_block_size=4, **kw)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.1))


def test_prefix_sharing_requires_paged():
    with pytest.raises(ValueError, match="prefix_sharing"):
        EngineConfig(prefix_sharing=True, paged=False)
    EngineConfig(prefix_sharing=True, paged=True)     # fine


def test_prefix_hit_skips_prefill_across_windows():
    """A repeated prompt re-served from the cache prefills only its
    uncached tail, and the greedy stream is unchanged."""
    L, gen = 14, 5                                    # 14 % 4 != 0: partial
    model, params = _model(TINY, 1, L)
    eng = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                  chunk=4, prefix_sharing=True)
    rng = np.random.default_rng(0)
    p = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)
    out1, rep1 = captured_run(eng, [Request(rid=0, tokens=p.copy(),
                                            max_new_tokens=gen)])
    assert rep1["prefix_hit_rate"] == 0.0             # cold cache
    assert rep1["prefill_chunks"] == 4                # ceil(14 / 4)
    eng.reset_metrics()
    out2, rep2 = captured_run(eng, [Request(rid=1, tokens=p.copy(),
                                            max_new_tokens=gen)])
    # longest block-aligned prefix: 12 of 14 prompt tokens
    assert rep2["prefix_hit_rate"] == pytest.approx(12 / 14)
    assert rep2["requests"][0]["cached_prefix_tokens"] == 12
    assert rep2["prefill_chunks"] == 1                # tail chunk only
    assert out2[1] == out1[0]
    assert eng._alloc.blocks_in_use == 0              # all chains released


def test_cow_on_full_prompt_hit():
    """A block-aligned prompt served entirely from the cache still needs
    its last position's logits: the recompute write lands in the final
    shared block, which is CoW'd — and the stream stays exact."""
    L, gen = 16, 5                                    # 16 % 4 == 0: full hit
    model, params = _model(TINY, 1, L)
    rng = np.random.default_rng(1)
    p = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)

    def mk(rid):
        return Request(rid=rid, tokens=p.copy(), max_new_tokens=gen)

    eng = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                  chunk=4, prefix_sharing=True)
    out1, _ = captured_run(eng, [mk(0)])
    eng.reset_metrics()
    out2, rep2 = captured_run(eng, [mk(1)])
    assert rep2["cow_copies"] == 1
    assert rep2["requests"][0]["cached_prefix_tokens"] == L - 1
    assert rep2["prefill_chunks"] == 1                # one-token recompute
    assert out2[1] == out1[0]


def test_differential_sharing_on_off():
    """Token-for-token identical greedy outputs with prefix sharing on vs
    off over a trace mixing shared prefixes (block-aligned and not),
    identical full prompts, mixed lengths, a block budget tight enough to
    preempt, and requests finishing exactly on a block boundary."""
    gen, bs = 6, 4
    max_prompt = 16
    model, params = _model(TINY, 3, max_prompt)
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, TINY.vocab_size, (12,)).astype(np.int32)
    full = rng.integers(0, TINY.vocab_size, (16,)).astype(np.int32)
    short = rng.integers(0, TINY.vocab_size, (7,)).astype(np.int32)

    def mk():
        reqs = []
        # shared 12-token prefix, tails of varying (non-)alignment
        for i, plen in enumerate([16, 14, 13]):
            t = np.concatenate(
                [prefix, np.arange(i, i + plen - 12, dtype=np.int32)])
            reqs.append(Request(rid=i, tokens=t, max_new_tokens=gen))
        # identical full prompts (full-hit CoW path); 16 + 6 is not a
        # block boundary, 16 + 4 is — rid 4 finishes exactly on one
        reqs.append(Request(rid=3, tokens=full.copy(), max_new_tokens=gen))
        reqs.append(Request(rid=4, tokens=full.copy(), max_new_tokens=4))
        # an unrelated short prompt
        reqs.append(Request(rid=5, tokens=short.copy(), max_new_tokens=gen))
        return reqs

    reqs_a, reqs_b = mk(), mk()
    for ra, rb in zip(reqs_a, reqs_b):
        assert (ra.tokens == rb.tokens).all()

    def run(sharing, reqs):
        eng = _engine(model, params, slots=3, prompt_len=max_prompt,
                      max_new=gen, chunk=4, prefix_sharing=sharing,
                      num_kv_blocks=9)
        out, rep = captured_run(eng, reqs)
        assert eng._alloc.blocks_in_use == 0
        return out, rep

    out_off, rep_off = run(False, reqs_a)
    out_on, rep_on = run(True, reqs_b)
    assert rep_on["preemptions"] > 0                  # budget really binds
    assert rep_on["prefix_hit_rate"] > 0
    for rid in out_off:
        assert out_on[rid] == out_off[rid], rid


def test_eos_id_finish_at_block_boundary():
    """An eos_id learned from a solo run, placed so the request finishes
    exactly when its write fills a block: commit/release ordering at the
    boundary must not corrupt later cache hits."""
    L, bs = 8, 4
    model, params = _model(TINY, 1, L)
    rng = np.random.default_rng(3)
    p = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)
    solo = _engine(model, params, slots=1, prompt_len=L, max_new=8, chunk=4,
                   prefix_sharing=True)
    out, _ = captured_run(solo, [Request(rid=0, tokens=p.copy(),
                                         max_new_tokens=8)])
    # pos after appending out[k] is L + k + 1; k = 3 lands on 12 % 4 == 0
    eos = out[0][3]
    eng = _engine(model, params, slots=1, prompt_len=L, max_new=8, chunk=4,
                  prefix_sharing=True)
    out1, _ = captured_run(eng, [Request(rid=1, tokens=p.copy(),
                                         max_new_tokens=8, eos_id=eos)])
    assert out1[1] == out[0][:4]                      # stopped at the eos
    eng.reset_metrics()
    # the boundary-finished sequence's blocks were retained: a rerun of the
    # same prompt hits the cache and still matches
    out2, rep2 = captured_run(eng, [Request(rid=2, tokens=p.copy(),
                                            max_new_tokens=8, eos_id=eos)])
    assert rep2["prefix_hit_rate"] > 0
    assert out2[2] == out1[1]


def test_preemption_keeps_shared_blocks_alive():
    """Preempting a request must only free blocks no other chain holds;
    recompute-on-resume re-matches the cached prefix (satellite: preemption
    x sharing)."""
    L, gen = 8, 8
    model, params = _model(TINY, 3, L)
    rng = np.random.default_rng(4)
    prefix = rng.integers(0, TINY.vocab_size, (8,)).astype(np.int32)

    def mk():
        out = []
        for i in range(5):
            t = prefix.copy()
            if i:                   # same 8-token prompt except last token
                t[-1] = (t[-1] + i) % TINY.vocab_size
            out.append(Request(rid=i, tokens=t, max_new_tokens=gen))
        return out

    solo = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                   chunk=4)
    out_ref, _ = captured_run(solo, mk())
    # 8 usable blocks for 3 slots of worst-case 4 blocks: forced preemption
    eng = _engine(model, params, slots=3, prompt_len=L, max_new=gen,
                  chunk=4, prefix_sharing=True, num_kv_blocks=8)
    out, rep = captured_run(eng, mk())
    assert rep["preemptions"] > 0
    assert rep["resume_cached_tokens"] > 0            # resume re-matched
    for rid in out_ref:
        assert out[rid] == out_ref[rid], rid
    assert eng._alloc.blocks_in_use == 0              # nothing leaked


def test_decode_cow_guard_on_shared_write_target():
    """If the block a decode step would write into is shared, the engine
    gives the writer a private copy first (copy-on-write guard) and the
    stream is unchanged."""
    L, gen = 6, 6                       # pos = 6 lands inside block 1
    model, params = _model(TINY, 1, L)
    rng = np.random.default_rng(5)
    p = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)

    def mk(rid):
        return Request(rid=rid, tokens=p.copy(), max_new_tokens=gen)

    solo = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                   chunk=3, prefix_sharing=True)
    out_ref, _ = captured_run(solo, [mk(0)])

    eng = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                  chunk=3, prefix_sharing=True)
    outputs = {}
    orig = eng._finish
    eng._finish = lambda st, now: (outputs.setdefault(st.req.rid,
                                                      list(st.output)),
                                   orig(st, now))
    eng.submit(mk(1))
    while not eng.active.any():
        eng.step()
    # another chain adopts the partially-filled block decode writes into
    blk = eng._alloc.chain(1)[eng.pos[0] // 4]
    eng._alloc.alloc_chain(999, 0, shared=[blk])
    assert eng._alloc.refcount(blk) == 2
    while eng.has_work():
        eng.step()
    assert eng.report()["cow_copies"] >= 1            # guard fired
    assert eng._alloc.chain(999) == (blk,)            # holder untouched
    assert outputs[1] == out_ref[0]


def test_lru_eviction_under_pressure_stays_exact():
    """More distinct prompts than the pool can cache: cold prefixes are
    evicted, allocation never deadlocks, streams match the no-sharing
    run."""
    L, gen = 8, 4
    model, params = _model(TINY, 2, L)

    def mk():
        return poisson_requests(10, rate=0.0, vocab_size=TINY.vocab_size,
                                prompt_len=L, max_new_tokens=gen, seed=6)

    def run(sharing):
        eng = _engine(model, params, slots=2, prompt_len=L, max_new=gen,
                      chunk=4, prefix_sharing=sharing, num_kv_blocks=8)
        out, rep = captured_run(eng, mk())
        return out, rep

    out_off, _ = run(False)
    out_on, rep_on = run(True)
    assert rep_on["evictions"] > 0
    for rid in out_off:
        assert out_on[rid] == out_off[rid], rid


def test_sharing_jit_entries_stable():
    """Admission off cache hits, CoW, eviction, and slot recycling never
    add a jit entry: one compilation per function, including the prefix
    gather and the CoW block copy."""
    L, gen = 8, 4
    model, params = _model(TINY, 2, L)
    eng = _engine(model, params, slots=2, prompt_len=L, max_new=gen,
                  chunk=4, prefix_sharing=True)
    eng.warmup()
    reqs = poisson_requests(6, rate=0.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=7,
                            shared_prefix_len=L)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 6
    assert rep["prefix_hit_rate"] > 0
    assert rep["cow_copies"] > 0                      # full-hit CoW ran live
    assert rep["jit_entries"] == {
        "prefill_chunk": 1, "decode": 1, "write_blocks": 1,
        "gather_prefix": 1, "copy_block": 1}, rep["jit_entries"]
    assert rep["recompiled_after_warmup"] is False
    assert rep["engine"]["prefix_sharing"] is True
