"""Hot-expert replication tests: rebalancer policy units, metrics routing,
and (in a multi-device subprocess, like test_distributed.py) the serving
differential — greedy token streams are identical across scheduling
policies and with replication on, while the jit caches never grow."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.topology import make_topology
from repro.serve.metrics import ServeMetrics
from repro.serve.rebalance import ExpertRebalancer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------------------------
# ExpertRebalancer policy (pure host-side numpy)
# ----------------------------------------------------------------------
def test_uniform_load_never_replicates():
    rb = ExpertRebalancer(make_topology(4, 8), 2)
    for _ in range(5):
        rb.observe(np.full(8, 10.0))
    dec = rb.propose()
    assert dec.hot_experts == []
    assert (dec.replica_ids == -1).all()
    assert not dec.changed              # init state is already all-empty


def test_hot_expert_replicated_on_non_hosts():
    topo = make_topology(4, 8)
    rb = ExpertRebalancer(topo, 2)
    load = np.full(8, 5.0)
    load[3] = 200.0                     # expert 3 is scorching
    for _ in range(3):
        rb.observe(load)
    dec = rb.propose()
    assert dec.hot_experts == [3]
    host = int(topo.host_of[3, 0])
    from repro.core.topology import local_slot_of
    src_row = host * topo.experts_per_rank + int(local_slot_of(topo)[host, 3])
    for g in range(4):
        if g == host:                   # host serves it from a local slot
            assert (dec.replica_ids[g] == -1).all()
        else:
            assert dec.replica_ids[g, 0] == 3
            # weight row = the host's stacked expert row for expert 3
            assert dec.weight_rows[g * 2 + 0] == src_row
    assert dec.changed
    # identical EMA -> identical proposal -> no swap
    dec2 = rb.propose()
    assert not dec2.changed
    assert (dec2.replica_ids == dec.replica_ids).all()


def test_ema_tracks_shifting_hotspot():
    """When the stream's hotspot drifts, the proposal follows it — the
    live-rebalancing behavior static placements cannot match."""
    rb = ExpertRebalancer(make_topology(4, 8), 1, ema_alpha=0.5)
    a = np.full(8, 1.0)
    a[0] = 100.0
    for _ in range(4):
        rb.observe(a)
    assert rb.propose().hot_experts == [0]
    b = np.full(8, 1.0)
    b[5] = 100.0
    for _ in range(6):
        rb.observe(b)
    dec = rb.propose()
    assert dec.hot_experts == [5]
    assert dec.changed


def test_per_layer_ema_is_independent_of_the_global_one():
    """``observe(load, layer=l)`` feeds the residency predictor's
    per-layer EMAs without perturbing anything the replication policy
    reads: the global EMA, ``hot()`` and ``propose()`` are bit-identical
    whether or not callers tag their observations with a layer."""
    topo = make_topology(4, 8)
    a = np.full(8, 1.0)
    a[2] = 50.0
    b = np.full(8, 1.0)
    b[6] = 50.0
    plain = ExpertRebalancer(topo, 1, ema_alpha=0.5)
    tagged = ExpertRebalancer(topo, 1, ema_alpha=0.5)
    for load, layer in ((a, 0), (b, 1), (a, 0), (b, 1)):
        plain.observe(load)
        tagged.observe(load, layer=layer)
    assert np.array_equal(plain.ema, tagged.ema)
    assert plain.hot() == tagged.hot()
    assert (plain.propose().replica_ids == tagged.propose().replica_ids).all()

    # never tagging leaves the per-layer table empty...
    assert plain.layer_ema == {}
    # ...and tagged layers fold separately: layer 0 only ever saw ``a``
    # (seed copy then one alpha=0.5 fold of the same vector => exactly a)
    assert set(tagged.layer_ema) == {0, 1}
    assert np.array_equal(tagged.layer_ema[0], a.astype(np.float64))
    assert np.array_equal(tagged.layer_ema[1], b.astype(np.float64))
    assert tagged.layer_ema[0][2] == 50.0 and tagged.layer_ema[0][6] == 1.0

    # a drifting layer follows the fold: 0.5 * 50 + 0.5 * 1 on slot 2
    tagged.observe(b, layer=0)
    assert tagged.layer_ema[0][2] == pytest.approx(25.5)
    assert tagged.layer_ema[0][6] == pytest.approx(25.5)
    # layer 1 untouched by layer 0's update
    assert np.array_equal(tagged.layer_ema[1], b.astype(np.float64))


def test_top_r_limit_and_threshold():
    rb = ExpertRebalancer(make_topology(4, 8), 2, hot_threshold=1.5)
    load = np.array([100.0, 90.0, 80.0, 1, 1, 1, 1, 1])
    rb.observe(load)
    hot = rb.hot()
    assert hot == [0, 1]                # R=2 caps the set, hottest first
    # threshold is mean-relative: scaling the whole vector changes nothing
    rb2 = ExpertRebalancer(make_topology(4, 8), 2, hot_threshold=1.5)
    rb2.observe(load * 1000)
    assert rb2.hot() == [0, 1]


def test_rebalancer_validates_shapes():
    rb = ExpertRebalancer(make_topology(4, 8), 1)
    with pytest.raises(ValueError):
        rb.observe(np.ones(5))
    with pytest.raises(ValueError):
        ExpertRebalancer(make_topology(4, 8), 0)
    with pytest.raises(ValueError):
        ExpertRebalancer(make_topology(4, 2), 1)   # E < G: no unique hosts


# ----------------------------------------------------------------------
# Metrics: vector diagnostics -> load_balance report
# ----------------------------------------------------------------------
def test_metrics_load_balance_section():
    m = ServeMetrics()
    m.record_step({"moved_units": 3.0, "send_drops": 0.0, "dest_drops": 1.0,
                   "rank_load": np.array([9.0, 1.0, 1.0, 1.0]),
                   "expert_load": np.arange(8, dtype=np.float64)},
                  4, phase="decode")
    m.record_step({"moved_units": 1.0, "send_drops": 0.0, "dest_drops": 0.0,
                   "rank_load": np.array([3.0, 1.0, 1.0, 1.0]),
                   "expert_load": np.arange(8, dtype=np.float64)},
                  4, phase="decode")
    rep = m.report()
    lb = rep["load_balance"]["decode"]
    assert lb["rank_load_mean"] == [6.0, 1.0, 1.0, 1.0]
    assert len(lb["expert_load_mean"]) == 8
    assert lb["max_load_mean"] == 6.0
    assert lb["straggler_wait_units"] == pytest.approx((6.0 + 1.5) / 2)
    assert lb["max_mean_ratio"] == pytest.approx((3.0 + 2.0) / 2)
    assert lb["dest_drops_total"] == 1.0
    # vectors never leak into the scalar "moe" means
    assert "decode/rank_load" not in rep["moe"]
    assert rep["moe"]["decode/moved_units"] == 2.0


def test_metrics_scalar_only_has_no_load_balance():
    m = ServeMetrics()
    m.record_step({"moved_units": 1.0}, 2, phase="decode")
    assert "load_balance" not in m.report()


# ----------------------------------------------------------------------
# Engine integration (multi-device subprocess)
# ----------------------------------------------------------------------
def _run(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_serve_policies_token_identical_and_jit_stable():
    """On a 4-rank expert-parallel mesh under 0.95 router skew:

    * greedy token streams are identical across harmoeny / round_robin /
      even_split AND harmoeny + live replication (scheduling moves compute,
      never changes math) — static_opt is excluded by design: its placement
      permutes the expert->weight-row mapping, so it is a different model;
    * with replication on, at least one hot-expert swap fires, the decode
      jit cache stays at ONE entry, and nothing recompiles after warmup;
    * harmoeny redistributes: its decode max/mean rank-load ratio beats
      round_robin's under skew, and drops stay zero everywhere.
    """
    _run("""
    import numpy as np, jax
    from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import MeshShape, build_model
    from repro.serve import (Request, ServeEngine, VirtualClock,
                             engine_config_for)

    def run(policy, rep_slots=0, interval=0):
        cfg = ModelConfig(
            name="tinymoe", family="moe", num_layers=2, d_model=32,
            num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
            head_dim=16, dtype="float32",
            moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                          d_ff_expert=32, policy=policy, router_skew=0.95,
                          q_tokens=1, num_foreign_slots=2,
                          num_replica_slots=rep_slots))
        mesh = make_host_mesh(1, 4)
        ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
        model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                            batch=4, seq_len=16, mesh_shape=ms, mesh=mesh)
        with mesh:
            params = model.init(jax.random.PRNGKey(0))
        ecfg = engine_config_for(cfg, max_slots=4, prompt_len=8,
                                 max_new_tokens=6, prefill_chunk=4,
                                 rebalance_interval=interval,
                                 replica_slots=rep_slots)
        eng = ServeEngine(model, params, ecfg, mesh=mesh,
                          clock=VirtualClock(0.5))
        eng.warmup()
        rng = np.random.default_rng(7)
        reqs = [Request(rid=i,
                        tokens=rng.integers(1, 60, size=8).astype(np.int32),
                        max_new_tokens=6, arrival_time=0.0)
                for i in range(6)]
        return eng.run(reqs)

    reports = {}
    for name, kw in (("harmoeny", {}),
                     ("round_robin", {}),
                     ("even_split", {}),
                     ("harmoeny+rep", dict(rep_slots=1, interval=3))):
        pol = name.split("+")[0]
        reports[name] = run(pol, **kw)

    # 1. token-identical greedy streams (drops are zero in every cell)
    streams = {}
    for name, rep in reports.items():
        lb = rep["load_balance"]["decode"]
        assert lb["send_drops_total"] == 0, (name, lb)
        assert lb["dest_drops_total"] == 0, (name, lb)
        streams[name] = tuple((r["rid"], r["n_generated"])
                              for r in rep["requests"])
    base = streams["harmoeny"]
    for name, s in streams.items():
        assert s == base, f"{name} diverged from harmoeny"

    # 2. replication fired and never recompiled
    rep = reports["harmoeny+rep"]
    assert rep["engine"]["replica_swaps"] >= 1
    assert rep["engine"]["hot_experts"], "EMA found no hot expert at 0.95"
    assert rep["jit_entries"]["decode"] == 1
    assert rep["jit_entries"]["replica_swap"] == 1
    assert rep["recompiled_after_warmup"] is False

    # 3. harmoeny balances better than round_robin under heavy skew
    r_h = reports["harmoeny"]["load_balance"]["decode"]["max_mean_ratio"]
    r_rr = reports["round_robin"]["load_balance"]["decode"]["max_mean_ratio"]
    assert r_h < r_rr, (r_h, r_rr)
    print("OK", r_h, r_rr)
    """)


def test_engine_config_validation():
    from repro.serve.engine import EngineConfig
    with pytest.raises(ValueError):
        EngineConfig(moe_policy="nope")
    with pytest.raises(ValueError):
        EngineConfig(rebalance_interval=4)        # no replica slots
    with pytest.raises(ValueError):
        EngineConfig(replica_slots=-1)
    EngineConfig(moe_policy="round_robin")        # valid override
    EngineConfig(replica_slots=2, rebalance_interval=8)


def test_engine_rejects_replica_slot_mismatch():
    """The model must be BUILT with the replica slots (shapes are static);
    asking the engine for slots the parameters lack is a config error."""
    _run("""
    import jax, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import MeshShape, build_model
    from repro.serve import ServeEngine, engine_config_for

    cfg = ModelConfig(
        name="tinymoe", family="moe", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
        head_dim=16, dtype="float32",
        moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_ff_expert=32,
                      policy="harmoeny", num_foreign_slots=2))
    mesh = make_host_mesh(1, 4)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=2, seq_len=16, mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    ecfg = engine_config_for(cfg, max_slots=2, prompt_len=8,
                             max_new_tokens=4, prefill_chunk=4,
                             replica_slots=1, rebalance_interval=2)
    try:
        ServeEngine(model, params, ecfg, mesh=mesh)
    except ValueError as e:
        assert "num_replica_slots" in str(e)
        print("OK")
    else:
        raise AssertionError("mismatched replica slots were accepted")
    """)
