"""Fused paged-attention decode kernel: interpret-mode parity vs the
gather reference, jit stability, and end-to-end serve-stream identity."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models.attention import paged_decode_attention
from repro.models.model import build_model
from repro.serve import (ServeEngine, VirtualClock, engine_config_for,
                         poisson_requests)

from _serve_helpers import captured_run


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


def _setup(seed, *, B, Hkv, rep, hd, bs, n_logical, lengths, dtype,
           q_len=1):
    """Physical pools + ragged block tables.  Each row's chain covers its
    length with distinct shuffled physical blocks; entries past the chain
    stay on the null block (0) — the engine's partially-filled-table
    convention ("holes").  ``q_len > 1`` builds a speculative-verify
    query window (each row's length must then be >= q_len)."""
    H = Hkv * rep
    num_blocks = 1 + B * n_logical
    P = num_blocks * bs
    key = jax.random.PRNGKey(seed)
    k_pool = jax.random.normal(jax.random.fold_in(key, 1),
                               (1, P, Hkv, hd)).astype(dtype)
    v_pool = jax.random.normal(jax.random.fold_in(key, 2),
                               (1, P, Hkv, hd)).astype(dtype)
    q = jax.random.normal(jax.random.fold_in(key, 3),
                          (B, q_len, H, hd)).astype(dtype)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, num_blocks))
    bt = np.zeros((B, n_logical), np.int32)
    i = 0
    for b in range(B):
        nv = -(-int(lengths[b]) // bs)
        bt[b, :nv] = perm[i:i + nv]
        i += nv
    return q, k_pool, v_pool, jnp.asarray(bt), \
        jnp.asarray(np.asarray(lengths, np.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rep", [1, 4])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_attention_parity(dtype, rep, softcap):
    """Kernel vs the standalone oracle AND the model-layer gather
    reference, over ragged per-row lengths (including the inactive-row
    length-1 convention) and null-block table holes."""
    bs, n_logical = 4, 6
    lengths = [1, 5, 11, 24]        # ragged; 24 = full chain, no holes
    q, kp, vp, bt, cl = _setup(0, B=4, Hkv=2, rep=rep, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=dtype)
    out = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                          softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, cl, block_size=bs,
                              softcap=softcap)
    gather = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs,
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gather, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_attention_block_size_sweep():
    """Kernel/reference parity holds at every block size (tile shape must
    not change the math)."""
    for bs, n_logical in [(2, 12), (4, 6), (8, 3)]:
        q, kp, vp, bt, cl = _setup(1, B=2, Hkv=2, rep=2, hd=8, bs=bs,
                                   n_logical=n_logical, lengths=[3, 17],
                                   dtype=jnp.float32)
        out = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                              interpret=True)
        ref = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("q_len", [1, 2, 4])
@pytest.mark.parametrize("rep", [1, 4])
def test_paged_attention_multiquery_parity(q_len, rep):
    """Multi-query tiles (speculative verify window): kernel vs oracle vs
    the model-layer gather over ragged lengths — including rows whose
    valid length is exactly the window (the engine's inactive-row
    convention at cache_len = q_len)."""
    bs, n_logical = 4, 6
    # lengths INCLUDE the q_len window positions; min length = q_len
    lengths = [q_len, 5 + q_len, 20 + q_len]
    q, kp, vp, bt, cl = _setup(3, B=3, Hkv=2, rep=rep, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=jnp.float32, q_len=q_len)
    out = paged_attention(q, kp, vp, bt, cl, block_size=bs, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, cl, block_size=bs)
    gather = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gather),
                               atol=2e-5, rtol=2e-5)


def test_multiquery_last_query_aligns_with_single_query():
    """The last query of a verify window attends exactly the positions a
    plain decode query at the same state does, so its output must agree
    with the q_len=1 call to float-associativity noise (~ulps; XLA may
    vectorize the two shapes differently).  The q_len == 1 path itself
    runs the original single-query mask on its own static branch, so
    plain decode through the extended kernel is bit-identical to the
    pre-multi-query kernel by construction."""
    bs, n_logical, S = 4, 6, 3
    lengths = [6, 9, 13]
    q, kp, vp, bt, cl = _setup(4, B=3, Hkv=2, rep=2, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=jnp.float32, q_len=S)
    multi = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                            interpret=True)
    single = paged_attention(q[:, -1:], kp, vp, bt, cl, block_size=bs,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(multi[:, -1:]),
                               np.asarray(single), atol=1e-6, rtol=1e-6)


def test_paged_attention_jit_stability():
    """One cache entry across decode steps: growing lengths and mutated
    block tables must re-use the same compilation."""
    bs, n_logical = 4, 6
    q, kp, vp, bt, cl = _setup(2, B=3, Hkv=2, rep=2, hd=8, bs=bs,
                               n_logical=n_logical, lengths=[2, 9, 15],
                               dtype=jnp.float32)
    fn = jax.jit(functools.partial(paged_attention, block_size=bs,
                                   softcap=0.0, interpret=True))
    outs = [fn(q, kp, vp, bt, cl)]
    for step in range(3):
        cl = cl + 1
        bt2 = jnp.where(bt == 0, (step + 1) % (bt.max() + 1), bt)
        outs.append(fn(q, kp, vp, bt2, cl))
    assert fn._cache_size() == 1
    ref = paged_decode_attention(q, kp, vp, bt, cl - 3, block_size=bs)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               atol=2e-5)


# ----------------------------------------------------------------------
# end-to-end: the serve engine with the kernel on vs off
# ----------------------------------------------------------------------
TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _paged_engine(fused: bool):
    model = build_model(TINY, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=3, seq_len=16)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = engine_config_for(TINY, max_slots=3, prompt_len=12,
                             max_new_tokens=6, prefill_chunk=4,
                             paged=True, kv_block_size=4,
                             fused_paged_attention=fused)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.05))


def test_engine_greedy_streams_identical_fused_vs_gather():
    """Greedy serve streams are token-for-token identical with the fused
    kernel on vs off, and the decode jit cache stays at one entry."""
    streams = {}
    for fused in (False, True):
        eng = _paged_engine(fused)
        reqs = poisson_requests(6, rate=50.0, vocab_size=TINY.vocab_size,
                                prompt_len=12, max_new_tokens=6, seed=7,
                                prompt_len_range=(5, 12))
        outs, rep = captured_run(eng, reqs)
        assert rep["jit_entries"]["decode"] == 1
        assert rep["engine"]["fused_paged_attention"] is fused
        streams[fused] = outs
    assert streams[False] == streams[True]


# ----------------------------------------------------------------------
# q-tiled prefill windows (the tentpole: one kernel for every phase)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rep", [1, 4])
@pytest.mark.parametrize("q_tile", [None, 7])
def test_qtiled_prefill_window_parity(dtype, rep, q_tile):
    """Large query windows (chunked-prefill regime) through the q-tiled
    kernel vs both references, with q_tile=7 forcing ragged last q tiles
    (48 = 6*7 + 6) and lengths mixing q_offset = 0 (prefill from
    scratch: length == S) with mid-sequence starts (length > S).  The
    cache_len contract: lengths INCLUDE the S-token query window."""
    bs, S = 4, 48
    n_logical = 20
    lengths = [S, S + 13, S + 30]       # q_offset 0 / 13 / 30
    q, kp, vp, bt, cl = _setup(5, B=3, Hkv=2, rep=rep, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=dtype, q_len=S)
    out = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                          q_tile=q_tile, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, cl, block_size=bs)
    gather = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gather, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("q_offset", [0, 48])
def test_slab_as_pool_matches_chunked_attention(q_offset):
    """The fused continue-prefill construction: a [B, S_max] slab viewed
    as per-row contiguous block chains with an identity table and
    cache_len = q_offset + S must agree with the reference
    ``chunked_attention(..., q_offset=q_offset)`` over the same slab —
    including garbage in the unwritten tail, which both paths must mask."""
    from repro.kernels.paged_attention.ops import largest_block_divisor
    from repro.models.attention import chunked_attention
    B, S_max, S, Hkv, rep, hd = 2, 144, 48, 2, 2, 8
    key = jax.random.PRNGKey(11)
    k_slab = jax.random.normal(jax.random.fold_in(key, 0),
                               (B, S_max, Hkv, hd))
    v_slab = jax.random.normal(jax.random.fold_in(key, 1),
                               (B, S_max, Hkv, hd))
    q = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, S, Hkv * rep, hd))
    bs = largest_block_divisor(S_max)
    nb = S_max // bs
    assert nb > 1                       # multi-block chains per row
    table = (jnp.arange(B, dtype=jnp.int32)[:, None] * nb
             + jnp.arange(nb, dtype=jnp.int32)[None, :])
    cl = jnp.full((B,), q_offset + S, jnp.int32)
    out = paged_attention(q, k_slab.reshape(1, B * S_max, Hkv, hd),
                          v_slab.reshape(1, B * S_max, Hkv, hd),
                          table, cl, block_size=bs, interpret=True)
    ref = chunked_attention(q, k_slab, v_slab, causal=True, chunk=32,
                            q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("q_offset", [0, 10])
def test_attention_block_fused_continue_prefill_matches_reference(q_offset):
    """attention_block's chunked-prefill continuation with use_pallas on
    (slab-as-pool q-tiled kernel) vs off (chunked reference): identical
    outputs and caches at chunk starts 0 and mid-sequence."""
    from repro.models.attention import (AttnCache, attention_block,
                                        init_attention)
    cfg = TINY
    B, S, S_max = 2, 10, 24
    key = jax.random.PRNGKey(13)
    p = init_attention(jax.random.fold_in(key, 0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    # pre-populated slab prefix [0, q_offset) + garbage tail
    slab = jax.random.normal(jax.random.fold_in(key, 2),
                             (B, S_max, Hkv, hd))
    outs, caches = {}, {}
    for fused in (False, True):
        cache = AttnCache(slab, slab * 0.5)
        y, nc = attention_block(x, p, cfg, causal=True, q_offset=q_offset,
                                cache=cache, cache_len=None,
                                attn_chunk=8, use_pallas=fused,
                                interpret=True, continue_prefill=True)
        outs[fused], caches[fused] = y, nc
    np.testing.assert_allclose(np.asarray(outs[True]),
                               np.asarray(outs[False]),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(caches[True].k),
                               np.asarray(caches[False].k), atol=0)


def test_strict_pallas_raises_on_inapplicable_fused_path():
    """pallas_strict turns the (previously silent) reference fallback into
    FusedPathUnavailable; non-strict still falls back, and the dispatch
    log counts it."""
    from repro.models import attention as A
    cfg = TINY.replace(sliding_window=8)    # binds: window < S_max = 24
    B, S, S_max = 2, 10, 24
    key = jax.random.PRNGKey(17)
    p = A.init_attention(jax.random.fold_in(key, 0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    slab = jnp.zeros((B, S_max, cfg.num_kv_heads, cfg.resolved_head_dim))
    cache = A.AttnCache(slab, slab)
    with pytest.raises(A.FusedPathUnavailable):
        A.attention_block(x, p, cfg, causal=True, q_offset=0, cache=cache,
                          attn_chunk=8, use_pallas=True, interpret=True,
                          continue_prefill=True, strict_pallas=True)
    A.reset_dispatch_log()
    y, _ = A.attention_block(x, p, cfg, causal=True, q_offset=0,
                             cache=cache, attn_chunk=8, use_pallas=True,
                             interpret=True, continue_prefill=True)
    assert y.shape == (B, S, cfg.d_model)
    assert A.fallback_counts().get("prefill_continue", 0) == 1
    A.reset_dispatch_log()


def test_engine_fused_everywhere_greedy_identical():
    """The full unified path — fused q-tiled prefill, prefix-tail resume,
    speculative k=4 verify — serves greedy streams token-identical to the
    all-reference engine, with no fused branch silently falling back."""
    streams = {}
    for fused in (False, True):
        model = build_model(TINY, ParallelConfig(attn_chunk=8,
                                                 loss_chunk=8),
                            batch=3, seq_len=16)
        params = model.init(jax.random.PRNGKey(0))
        ecfg = engine_config_for(TINY, max_slots=3, prompt_len=16,
                                 max_new_tokens=8, prefill_chunk=4,
                                 paged=True, kv_block_size=4,
                                 prefix_sharing=True, speculative_k=4,
                                 fused_paged_attention=fused)
        eng = ServeEngine(model, params, ecfg, clock=VirtualClock(0.05))
        reqs = poisson_requests(6, rate=50.0, vocab_size=TINY.vocab_size,
                                prompt_len=16, max_new_tokens=8, seed=11,
                                shared_prefix_len=8)
        outs, rep = captured_run(eng, reqs)
        streams[fused] = outs
        if fused:
            assert rep["attention_fallbacks"] == {}
            disp = rep["attention_dispatch"]
            assert disp["prefill_continue"]["fused"]
            assert disp["verify"]["fused"]
        assert set(rep["phases"]) >= {"prefill", "verify"}
        for ph in rep["phases"].values():
            assert ph["tokens"] > 0 and ph["kv_bytes_touched"] > 0
    assert streams[False] == streams[True]


def test_moe_engine_fused_gmm_greedy_identical():
    """Grouped-GEMM expert FFN on the serve path (prefill chunks AND the
    [B, k+1] verify batch): greedy streams token-identical with
    fused_moe_gmm on vs off."""
    from repro.configs.base import MoEConfig
    cfg = ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
        dtype="float32",
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_ff_expert=32,
                      policy="harmoeny", num_foreign_slots=1))
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import MeshShape
    streams = {}
    for fused in (False, True):
        mesh = make_host_mesh(1, 1)
        ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
        model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                            batch=2, seq_len=16, mesh_shape=ms, mesh=mesh)
        with mesh:
            params = model.init(jax.random.PRNGKey(1))
        ecfg = engine_config_for(cfg, max_slots=2, prompt_len=16,
                                 max_new_tokens=6, prefill_chunk=8,
                                 paged=True, kv_block_size=4,
                                 speculative_k=3,
                                 fused_paged_attention=fused,
                                 fused_moe_gmm=fused)
        eng = ServeEngine(model, params, ecfg, mesh=mesh,
                          clock=VirtualClock(0.05))
        reqs = poisson_requests(3, rate=50.0, vocab_size=cfg.vocab_size,
                                prompt_len=16, max_new_tokens=6, seed=5)
        outs, rep = captured_run(eng, reqs)
        assert rep["engine"]["fused_moe_gmm"] is fused
        streams[fused] = outs
    assert streams[False] == streams[True]
