"""Fused paged-attention decode kernel: interpret-mode parity vs the
gather reference, jit stability, and end-to-end serve-stream identity."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models.attention import paged_decode_attention
from repro.models.model import build_model
from repro.serve import (ServeEngine, VirtualClock, engine_config_for,
                         poisson_requests)

from _serve_helpers import captured_run


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


def _setup(seed, *, B, Hkv, rep, hd, bs, n_logical, lengths, dtype,
           q_len=1):
    """Physical pools + ragged block tables.  Each row's chain covers its
    length with distinct shuffled physical blocks; entries past the chain
    stay on the null block (0) — the engine's partially-filled-table
    convention ("holes").  ``q_len > 1`` builds a speculative-verify
    query window (each row's length must then be >= q_len)."""
    H = Hkv * rep
    num_blocks = 1 + B * n_logical
    P = num_blocks * bs
    key = jax.random.PRNGKey(seed)
    k_pool = jax.random.normal(jax.random.fold_in(key, 1),
                               (1, P, Hkv, hd)).astype(dtype)
    v_pool = jax.random.normal(jax.random.fold_in(key, 2),
                               (1, P, Hkv, hd)).astype(dtype)
    q = jax.random.normal(jax.random.fold_in(key, 3),
                          (B, q_len, H, hd)).astype(dtype)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, num_blocks))
    bt = np.zeros((B, n_logical), np.int32)
    i = 0
    for b in range(B):
        nv = -(-int(lengths[b]) // bs)
        bt[b, :nv] = perm[i:i + nv]
        i += nv
    return q, k_pool, v_pool, jnp.asarray(bt), \
        jnp.asarray(np.asarray(lengths, np.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rep", [1, 4])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_attention_parity(dtype, rep, softcap):
    """Kernel vs the standalone oracle AND the model-layer gather
    reference, over ragged per-row lengths (including the inactive-row
    length-1 convention) and null-block table holes."""
    bs, n_logical = 4, 6
    lengths = [1, 5, 11, 24]        # ragged; 24 = full chain, no holes
    q, kp, vp, bt, cl = _setup(0, B=4, Hkv=2, rep=rep, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=dtype)
    out = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                          softcap=softcap, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, cl, block_size=bs,
                              softcap=softcap)
    gather = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs,
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gather, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_paged_attention_block_size_sweep():
    """Kernel/reference parity holds at every block size (tile shape must
    not change the math)."""
    for bs, n_logical in [(2, 12), (4, 6), (8, 3)]:
        q, kp, vp, bt, cl = _setup(1, B=2, Hkv=2, rep=2, hd=8, bs=bs,
                                   n_logical=n_logical, lengths=[3, 17],
                                   dtype=jnp.float32)
        out = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                              interpret=True)
        ref = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("q_len", [1, 2, 4])
@pytest.mark.parametrize("rep", [1, 4])
def test_paged_attention_multiquery_parity(q_len, rep):
    """Multi-query tiles (speculative verify window): kernel vs oracle vs
    the model-layer gather over ragged lengths — including rows whose
    valid length is exactly the window (the engine's inactive-row
    convention at cache_len = q_len)."""
    bs, n_logical = 4, 6
    # lengths INCLUDE the q_len window positions; min length = q_len
    lengths = [q_len, 5 + q_len, 20 + q_len]
    q, kp, vp, bt, cl = _setup(3, B=3, Hkv=2, rep=rep, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=jnp.float32, q_len=q_len)
    out = paged_attention(q, kp, vp, bt, cl, block_size=bs, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, cl, block_size=bs)
    gather = paged_decode_attention(q, kp, vp, bt, cl, block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gather),
                               atol=2e-5, rtol=2e-5)


def test_multiquery_last_query_aligns_with_single_query():
    """The last query of a verify window attends exactly the positions a
    plain decode query at the same state does, so its output must agree
    with the q_len=1 call to float-associativity noise (~ulps; XLA may
    vectorize the two shapes differently).  The q_len == 1 path itself
    runs the original single-query mask on its own static branch, so
    plain decode through the extended kernel is bit-identical to the
    pre-multi-query kernel by construction."""
    bs, n_logical, S = 4, 6, 3
    lengths = [6, 9, 13]
    q, kp, vp, bt, cl = _setup(4, B=3, Hkv=2, rep=2, hd=16, bs=bs,
                               n_logical=n_logical, lengths=lengths,
                               dtype=jnp.float32, q_len=S)
    multi = paged_attention(q, kp, vp, bt, cl, block_size=bs,
                            interpret=True)
    single = paged_attention(q[:, -1:], kp, vp, bt, cl, block_size=bs,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(multi[:, -1:]),
                               np.asarray(single), atol=1e-6, rtol=1e-6)


def test_paged_attention_jit_stability():
    """One cache entry across decode steps: growing lengths and mutated
    block tables must re-use the same compilation."""
    bs, n_logical = 4, 6
    q, kp, vp, bt, cl = _setup(2, B=3, Hkv=2, rep=2, hd=8, bs=bs,
                               n_logical=n_logical, lengths=[2, 9, 15],
                               dtype=jnp.float32)
    fn = jax.jit(functools.partial(paged_attention, block_size=bs,
                                   softcap=0.0, interpret=True))
    outs = [fn(q, kp, vp, bt, cl)]
    for step in range(3):
        cl = cl + 1
        bt2 = jnp.where(bt == 0, (step + 1) % (bt.max() + 1), bt)
        outs.append(fn(q, kp, vp, bt2, cl))
    assert fn._cache_size() == 1
    ref = paged_decode_attention(q, kp, vp, bt, cl - 3, block_size=bs)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(ref),
                               atol=2e-5)


# ----------------------------------------------------------------------
# end-to-end: the serve engine with the kernel on vs off
# ----------------------------------------------------------------------
TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _paged_engine(fused: bool):
    model = build_model(TINY, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=3, seq_len=16)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = engine_config_for(TINY, max_slots=3, prompt_len=12,
                             max_new_tokens=6, prefill_chunk=4,
                             paged=True, kv_block_size=4,
                             fused_paged_attention=fused)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.05))


def test_engine_greedy_streams_identical_fused_vs_gather():
    """Greedy serve streams are token-for-token identical with the fused
    kernel on vs off, and the decode jit cache stays at one entry."""
    streams = {}
    for fused in (False, True):
        eng = _paged_engine(fused)
        reqs = poisson_requests(6, rate=50.0, vocab_size=TINY.vocab_size,
                                prompt_len=12, max_new_tokens=6, seed=7,
                                prompt_len_range=(5, 12))
        outs, rep = captured_run(eng, reqs)
        assert rep["jit_entries"]["decode"] == 1
        assert rep["engine"]["fused_paged_attention"] is fused
        streams[fused] = outs
    assert streams[False] == streams[True]
