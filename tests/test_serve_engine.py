"""Serving-engine integration tests on tiny CPU models.

Covers the ISSUE-mandated invariants: chunked prefill + slotted decode
reproduce the one-shot driver token-for-token; finished slots are recycled
by queued requests with ZERO recompilation (jit cache stays at one entry per
function); TTFT/TPOT metrics are arithmetically consistent on a
deterministic clock; the MoE path threads per-step skew keys and surfaces
HarMoEny schedule diagnostics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.models.model import MeshShape, build_model
from repro.serve import (Request, ServeEngine, VirtualClock,
                         engine_config_for, poisson_requests)

from _serve_helpers import captured_run

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _model(cfg, batch, seq_len):
    m = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                    batch=batch, seq_len=seq_len)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(cfg, model, params, *, slots, prompt_len, max_new, chunk, **kw):
    ecfg = engine_config_for(cfg, max_slots=slots, prompt_len=prompt_len,
                             max_new_tokens=max_new, prefill_chunk=chunk,
                             **kw)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.5))


def _reference_tokens(model, params, prompt, gen, s_max):
    """One-shot prefill + lockstep greedy decode (the old serve driver)."""
    logits, caches, pos, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, s_max=s_max)
    out = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(gen - 1):
        logits, caches, pos, _ = model.decode_step(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


@pytest.mark.parametrize("paged", [False, True])
def test_engine_matches_one_shot_driver(paged):
    """Chunked prefill + slotted decode == one-shot prefill + decode,
    token for token (partial final chunk included: 10 = 4 + 4 + 2) — for
    the slab pool AND the paged block-table pool."""
    L, gen = 10, 6
    model, params = _model(TINY, 1, L)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)

    eng = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=gen,
                  chunk=4, paged=paged, kv_block_size=4)
    rep = eng.run([Request(rid=0, tokens=prompt, max_new_tokens=gen)])
    got = rep["requests"][0]
    ref = _reference_tokens(model, params, prompt, gen,
                            eng.ecfg.max_seq_len)
    st_outputs = [r for r in eng.metrics.requests if r.rid == 0]
    assert got["n_generated"] == gen == len(ref)
    # recover the engine's emitted tokens from the completed state record
    assert rep["n_requests"] == 1
    # engine stores outputs on RequestState; re-run to capture them directly
    eng2 = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=gen,
                   chunk=4, paged=paged, kv_block_size=4)
    outputs, _ = captured_run(
        eng2, [Request(rid=0, tokens=prompt, max_new_tokens=gen)])
    assert outputs[0] == ref


def test_slot_recycling_and_zero_recompilation():
    """6 requests through 2 slots: every slot is reused, all requests finish,
    and each jitted function compiled exactly once."""
    L, gen, slots = 8, 4, 2
    model, params = _model(TINY, slots, L)
    eng = _engine(TINY, model, params, slots=slots, prompt_len=L,
                  max_new=gen, chunk=4)
    reqs = poisson_requests(6, rate=0.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=0)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 6
    assert rep["total_new_tokens"] == 6 * gen
    used = [s for _, s in eng.slot_history]
    assert sorted(set(used)) == [0, 1]          # both slots exercised
    assert len(used) == 6                        # every request got a slot
    assert max(np.bincount(used)) >= 2           # recycling happened
    assert rep["jit_entries"] == {"prefill_chunk": 1, "decode": 1,
                                  "write_slot": 1}, rep["jit_entries"]


def test_mixed_lengths_decode_together():
    """Two requests of different prompt lengths share one decode batch and
    each still reproduces its single-request token stream (per-slot
    position vectors)."""
    model, params = _model(TINY, 2, 12)
    rng = np.random.default_rng(7)
    pa = rng.integers(0, TINY.vocab_size, (12,)).astype(np.int32)
    pb = rng.integers(0, TINY.vocab_size, (5,)).astype(np.int32)
    gen = 5

    def run_engine(reqs, slots):
        eng = _engine(TINY, model, params, slots=slots, prompt_len=12,
                      max_new=gen, chunk=4)
        outputs, _ = captured_run(eng, reqs)
        return outputs

    together = run_engine(
        [Request(rid=0, tokens=pa, max_new_tokens=gen),
         Request(rid=1, tokens=pb, max_new_tokens=gen)], slots=2)
    solo_a = run_engine([Request(rid=0, tokens=pa, max_new_tokens=gen)],
                        slots=2)
    solo_b = run_engine([Request(rid=1, tokens=pb, max_new_tokens=gen)],
                        slots=2)
    assert together[0] == solo_a[0]
    assert together[1] == solo_b[1]


def test_ttft_tpot_metrics_consistent():
    """On a deterministic clock the recorded latency identities hold."""
    L, gen = 8, 5
    model, params = _model(TINY, 2, L)
    eng = _engine(TINY, model, params, slots=2, prompt_len=L, max_new=gen,
                  chunk=4)
    reqs = poisson_requests(4, rate=2.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=5)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 4
    for rec in eng.metrics.requests:
        assert rec.first_token_time >= rec.admitted_time >= rec.arrival_time
        assert rec.finish_time >= rec.first_token_time
        assert rec.ttft >= 0 and rec.tpot > 0
        # e2e decomposes exactly into TTFT + (n-1) * TPOT
        assert rec.e2e == pytest.approx(
            rec.ttft + rec.tpot * (rec.n_generated - 1))
        assert rec.n_generated == gen
    assert rep["ttft"]["p50"] <= rep["ttft"]["p99"]


def test_run_rebases_clock_on_reuse():
    """Regression: the engine clock is zeroed at construction, but request
    arrival times start at 0 — without a rebase at run() start, warmup and
    previous runs' time leaks into TTFT/queue_delay and every open-loop
    arrival is already in the past (rate cells degenerate to closed batch).
    """
    L, gen = 8, 3
    model, params = _model(TINY, 2, L)
    clock = VirtualClock(0.5)
    ecfg = engine_config_for(TINY, max_slots=2, prompt_len=L,
                             max_new_tokens=gen, prefill_chunk=4)
    eng = ServeEngine(model, params, ecfg, clock=clock)
    eng.warmup()
    clock.wait(1000.0)                      # time burned before measuring
    rep = eng.run(poisson_requests(2, rate=0.0, vocab_size=TINY.vocab_size,
                                   prompt_len=L, max_new_tokens=gen, seed=0))
    # run() rebased the clock: warmup + idle time do not leak into latency
    assert all(r["ttft"] < 1000.0 and r["e2e"] < 1000.0
               for r in rep["requests"])

    clock.wait(500.0)                       # idle drift between runs
    eng.reset_metrics()
    reqs = poisson_requests(4, rate=1.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=1)
    assert max(r.arrival_time for r in reqs) > 0.0
    rep = eng.run(reqs)
    for rec in rep["requests"]:
        # timestamps restart near 0: no leakage of the inter-run 500s
        assert rec["ttft"] < 500.0
        assert rec["e2e"] < 500.0
    # open-loop arrivals stayed in the future at run start: the last request
    # was admitted on the rebased timeline, after its (positive) arrival
    last = max(eng.metrics.requests, key=lambda r: r.arrival_time)
    assert 500.0 > last.admitted_time >= last.arrival_time > 0.0

    # submit()-then-run() rebases too: queued-but-unadmitted requests carry
    # no clock-derived timestamps, so they must not block the rebase
    eng.reset_metrics()
    clock.wait(800.0)
    eng.submit(Request(rid=99, tokens=np.zeros(L, np.int32),
                       max_new_tokens=gen))
    rep = eng.run()
    assert rep["requests"][0]["ttft"] < 800.0

    # consecutive run()s WITHOUT reset_metrics() accumulate into one window
    # on one continuous clock — no rebase once timestamps exist, else the
    # overlapping timelines would inflate throughput
    t_mid = clock.t
    eng.run([Request(rid=100, tokens=np.zeros(L, np.int32),
                     max_new_tokens=gen)])
    rec2 = next(r for r in eng.metrics.requests if r.rid == 100)
    assert rec2.first_token_time > t_mid


def test_warmup_requires_idle_engine():
    """warmup() overwrites pool slot 0 and the scratch cache, so it must
    refuse to run while requests are queued or occupy slots."""
    L = 8
    model, params = _model(TINY, 1, L)
    eng = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=2,
                  chunk=4)
    eng.submit(Request(rid=0, tokens=np.zeros(L, np.int32),
                       max_new_tokens=2))
    eng.reset_metrics()      # queued-only work holds no clock timestamps
    with pytest.raises(RuntimeError, match="idle"):
        eng.warmup()
    eng.run()                                # drain, engine idle again
    eng.warmup()                             # now fine


def test_eos_frees_slot_early():
    """A request hitting EOS mid-stream finishes and frees its slot."""
    L, gen = 8, 16
    model, params = _model(TINY, 1, L)
    eng = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=gen,
                  chunk=4)
    # pick the EOS id from a dry run: the 2nd emitted token
    probe = _reference_tokens(model, params,
                              np.arange(L).astype(np.int32), 3,
                              eng.ecfg.max_seq_len)
    eos = probe[1]
    rep = eng.run([Request(rid=0, tokens=np.arange(L).astype(np.int32),
                           max_new_tokens=gen, eos_id=eos)])
    rec = rep["requests"][0]
    assert rec["n_generated"] == 2               # stopped at the EOS token
    assert not eng.has_work()
    assert list(eng.free_slots) == [0]


def test_request_validation():
    L = 8
    model, params = _model(TINY, 1, L)
    with pytest.raises(ValueError, match="chunks_per_step"):
        ecfg = engine_config_for(TINY, max_slots=1, prompt_len=L,
                                 max_new_tokens=4, prefill_chunk=4)
        ServeEngine(model, params,
                    dataclasses.replace(ecfg, chunks_per_step=0))
    eng = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=4,
                  chunk=4)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=0, tokens=np.zeros(64, np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=1, tokens=np.zeros((L,), np.int32),
                           max_new_tokens=1000))


def test_moe_engine_diagnostics_and_skew_keys():
    """Reduced-family MoE model: the engine threads a fresh skew key into
    every decode step (the old driver's bug) and HarMoEny schedule
    diagnostics land in the report."""
    cfg = ModelConfig(
        name="tiny-moe", family="moe", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
        dtype="float32",
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_ff_expert=32,
                      policy="harmoeny", router_skew=0.9,
                      num_foreign_slots=1))
    mesh = make_host_mesh(1, 1)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=2, seq_len=8, mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    ecfg = engine_config_for(cfg, max_slots=2, prompt_len=8,
                             max_new_tokens=3, prefill_chunk=4)
    eng = ServeEngine(model, params, ecfg, mesh=mesh,
                      clock=VirtualClock(0.5))
    assert eng._skew                              # keys will be threaded
    keys = []
    orig = eng._next_key

    def spy(stream, idx):
        k = orig(stream, idx)
        keys.append(None if k is None else np.asarray(k).tolist())
        return k

    eng._next_key = spy
    rep = eng.run(poisson_requests(3, rate=0.0, vocab_size=cfg.vocab_size,
                                   prompt_len=8, max_new_tokens=3, seed=2))
    assert rep["n_requests"] == 3
    assert "moe" in rep and any("moved_units" in k for k in rep["moe"])
    # inactive slots are masked out of routing: per-step expert load can
    # never exceed the active tokens' unit count (<= 2 slots * top-2)
    assert max(eng.metrics.moe_diags["decode/max_load_before"]) <= 4
    # every threaded key is distinct — no step reuses the skew stream
    as_tuples = [tuple(k) for k in keys if k is not None]
    assert len(as_tuples) == len(set(as_tuples)) and as_tuples
    assert rep["jit_entries"]["decode"] == 1


def test_engine_rejects_unsupported_families():
    cfg = get_config("mamba2-2.7b").reduced()
    model, params = _model(cfg, 1, 8)
    with pytest.raises(NotImplementedError):
        _engine(cfg, model, params, slots=1, prompt_len=8, max_new=2,
                chunk=4)


# ----------------------------------------------------------------------
# sampling (temperature + top-k behind EngineConfig)
# ----------------------------------------------------------------------
def test_topk1_sampling_is_greedy():
    """temperature > 0 with top_k=1 must reproduce the greedy stream token
    for token — the sampler's only candidate is the argmax."""
    L, gen = 8, 6
    model, params = _model(TINY, 2, L)
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)
    req = lambda: [Request(rid=0, tokens=prompt, max_new_tokens=gen)]  # noqa

    greedy = _engine(TINY, model, params, slots=2, prompt_len=L,
                     max_new=gen, chunk=4)
    sampled = _engine(TINY, model, params, slots=2, prompt_len=L,
                      max_new=gen, chunk=4, temperature=0.8, top_k=1)
    out_g, _ = captured_run(greedy, req())
    out_s, rep = captured_run(sampled, req())
    assert out_g[0] == out_s[0]
    # sampling is folded into the one decode entry, never a second one
    assert rep["jit_entries"]["decode"] == 1


def test_sampling_deterministic_and_in_vocab():
    """Same seed => same sampled stream; tokens stay inside the real vocab
    (padded logit rows are masked to -inf before the draw)."""
    L, gen = 8, 8
    model, params = _model(TINY, 2, L)
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, TINY.vocab_size, (L,)).astype(np.int32)

    def one():
        eng = _engine(TINY, model, params, slots=2, prompt_len=L,
                      max_new=gen, chunk=4, temperature=1.5, top_k=5)
        return captured_run(
            eng, [Request(rid=0, tokens=prompt, max_new_tokens=gen)])

    out_a, rep = one()
    out_b, _ = one()
    assert out_a[0] == out_b[0]
    assert all(0 <= t < TINY.vocab_size for t in out_a[0])
    assert rep["jit_entries"]["decode"] == 1
    # temperature alone must not degenerate to greedy: compare with greedy
    greedy = _engine(TINY, model, params, slots=2, prompt_len=L,
                     max_new=gen, chunk=4)
    out_g, _ = captured_run(
        greedy, [Request(rid=0, tokens=prompt, max_new_tokens=gen)])
    # not guaranteed different in principle, but at T=1.5 over 8 draws the
    # streams coinciding would be a (tested-against) regression smell
    assert out_a[0] != out_g[0]


# ----------------------------------------------------------------------
# trace-driven arrivals + empty-window report
# ----------------------------------------------------------------------
def test_trace_roundtrip_through_engine(tmp_path):
    """A JSON arrival trace drives ServeEngine.run end to end: every record
    becomes a finished request, admitted no earlier than its arrival."""
    import json

    from repro.serve import load_trace
    L, gen = 8, 3
    records = [
        {"rid": 7, "arrival_time": 0.0, "prompt_len": L,
         "max_new_tokens": gen},
        {"rid": 8, "arrival_time": 2.0, "tokens": list(range(1, L + 1)),
         "max_new_tokens": gen},
        {"rid": 9, "arrival_time": 4.5, "prompt_len": L - 2,
         "max_new_tokens": gen},
    ]
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(records))
    reqs = load_trace(str(path), vocab_size=TINY.vocab_size)
    assert [r.rid for r in reqs] == [7, 8, 9]
    assert list(reqs[1].tokens) == list(range(1, L + 1))

    model, params = _model(TINY, 2, L)
    eng = _engine(TINY, model, params, slots=2, prompt_len=L, max_new=gen,
                  chunk=4)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 3
    by_rid = {r["rid"]: r for r in rep["requests"]}
    assert set(by_rid) == {7, 8, 9}
    for rec in eng.metrics.requests:
        assert rec.admitted_time >= rec.arrival_time
    assert by_rid[9]["arrival_time"] == 4.5


def test_report_on_empty_window_is_json_safe():
    """report() before any request completes: percentile reductions come
    back as None (never NaN), the report serializes under strict JSON, and
    running zero requests keeps it that way."""
    import json

    L = 8
    model, params = _model(TINY, 1, L)
    eng = _engine(TINY, model, params, slots=1, prompt_len=L, max_new=2,
                  chunk=4)
    rep = eng.report()
    assert rep["n_requests"] == 0
    assert rep["ttft"]["p50"] is None and rep["tpot"]["mean"] is None
    assert rep["throughput_tok_s"] is None
    json.dumps(rep, allow_nan=False)        # would raise on NaN/inf
    rep = eng.run([])                       # draining nothing also reports
    json.dumps(rep, allow_nan=False)
    assert rep["mean_occupancy"] == 0.0 and rep["max_occupancy"] == 0
