"""Speculative decoding: engine differential (greedy streams token-exact
with speculation on vs off, including fused-kernel, prefix-sharing, and
preemption interactions), proposer units, and the rejection sampler's
distribution identity with the base sampler (reusing the support-set
harness of tests/test_sampling_twins.py)."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import build_model
from repro.serve import (EngineConfig, NGramProposer, Request, ServeEngine,
                         VirtualClock, engine_config_for, greedy_verify,
                         make_proposer, poisson_requests, rejection_verify,
                         sample_np, truncated_probs_np)

from _serve_helpers import captured_run

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _build(spec_k, *, slots=3, prompt_len=12, gen=8, chunk=4, bs=4,
           num_kv_blocks=0, prefix_sharing=False, fused=False,
           eos_id=None, temperature=0.0, top_k=0, top_p=1.0):
    model = build_model(TINY, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=slots, seq_len=prompt_len)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = engine_config_for(TINY, max_slots=slots, prompt_len=prompt_len,
                             max_new_tokens=gen, prefill_chunk=chunk,
                             paged=True, kv_block_size=bs,
                             num_kv_blocks=num_kv_blocks,
                             prefix_sharing=prefix_sharing,
                             fused_paged_attention=fused, eos_id=eos_id,
                             speculative_k=spec_k, temperature=temperature,
                             top_k=top_k, top_p=top_p)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.05))


def _repetitive_requests(n, *, prompt_len=12, gen=8, seed=0, eos_id=None):
    """Prompts tiled from a short motif — the regime prompt-lookup
    drafting accepts on."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        motif = rng.integers(0, TINY.vocab_size, (3,)).astype(np.int32)
        toks = np.tile(motif, -(-prompt_len // 3))[:prompt_len]
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=gen,
                            eos_id=eos_id))
    return reqs


# ----------------------------------------------------------------------
# engine differential: greedy streams token-exact, speculation on vs off
# ----------------------------------------------------------------------
def test_greedy_streams_identical_across_speculative_k():
    """The acceptance criterion: greedy serve streams are token-identical
    with speculative_k in {0, 2, 4}, and the decode jit cache holds one
    entry (the verify step is recompilation-free)."""
    streams = {}
    for k in (0, 2, 4):
        eng = _build(k)
        reqs = poisson_requests(6, rate=50.0, vocab_size=TINY.vocab_size,
                                prompt_len=12, max_new_tokens=8, seed=7,
                                prompt_len_range=(5, 12))
        outs, rep = captured_run(eng, reqs)
        assert rep["jit_entries"]["decode"] == 1
        if k:
            assert rep["engine"]["speculative_k"] == k
            assert rep["speculative"]["committed_tokens"] > 0
        streams[k] = outs
    assert streams[0] == streams[2] == streams[4]


def test_greedy_streams_identical_fused_multiquery_kernel():
    """Same differential through the fused multi-query kernel tiles: the
    Pallas verify path must commit the identical greedy stream."""
    streams = {}
    for fused in (False, True):
        eng = _build(3, fused=fused)
        outs, rep = captured_run(eng, _repetitive_requests(5))
        assert rep["engine"]["fused_paged_attention"] is fused
        streams[fused] = outs
    assert streams[False] == streams[True]


def test_speculation_accepts_on_repetitive_text():
    """On a tiled-motif workload the n-gram proposer must actually win:
    acceptance > 0 and per-slot decode steps per committed token < 1.0
    (the paper-facing speculative metric)."""
    eng = _build(3, gen=16)
    _, rep = captured_run(eng, _repetitive_requests(4, gen=16))
    sp = rep["speculative"]
    assert sp["accepted"] > 0
    assert sp["steps_per_committed_token"] < 1.0
    assert sp["tokens_per_step"] > 1.0


def test_eos_mid_window_streams_exact():
    """EOS appearing inside an accepted draft run must cut the stream at
    exactly the same token as non-speculative decode (no post-EOS
    commits)."""
    base = _build(0, gen=16)
    outs0, _ = captured_run(base, _repetitive_requests(4, gen=16, seed=3))
    # pick an eos id that actually occurs mid-stream in the base run
    candidates = [t for toks in outs0.values() for t in toks[1:-1]]
    assert candidates, "expected a usable mid-stream token"
    eos = candidates[0]
    streams = {}
    for k in (0, 3):
        eng = _build(k, gen=16, eos_id=eos)
        outs, _ = captured_run(
            eng, _repetitive_requests(4, gen=16, seed=3, eos_id=eos))
        for toks in outs.values():
            assert eos not in toks[:-1]      # nothing committed past EOS
        streams[k] = outs
    assert streams[0] == streams[3]


def test_speculative_with_prefix_sharing_and_preemption():
    """The full interaction: prefix sharing + a tight block budget that
    forces preemption-by-recompute + speculative verify.  Greedy streams
    must stay token-exact vs the non-speculative engine at the same
    budget, and the CoW guard must keep rejected-draft garbage out of
    shared blocks (stream equality would break if it leaked)."""
    rng = np.random.default_rng(11)
    shared = rng.integers(0, TINY.vocab_size, (8,)).astype(np.int32)

    def reqs():
        out = []
        for i in range(6):
            tail = rng.integers(0, TINY.vocab_size, (4,)).astype(np.int32)
            out.append(Request(rid=i, tokens=np.concatenate([shared, tail]),
                               max_new_tokens=10))
        return out

    streams, reports = {}, {}
    for k in (0, 3):
        rng = np.random.default_rng(11)       # same workload both runs
        shared = rng.integers(0, TINY.vocab_size, (8,)).astype(np.int32)
        eng = _build(k, slots=3, prompt_len=12, gen=10,
                     num_kv_blocks=14, prefix_sharing=True)
        outs, rep = captured_run(eng, reqs())
        streams[k] = outs
        reports[k] = rep
    assert streams[0] == streams[3]
    # the tight budget must actually exercise the machinery
    assert reports[3]["preemptions"] > 0 or reports[0]["preemptions"] > 0
    assert reports[3]["prefix_hit_rate"] > 0


def test_speculative_requires_paged():
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(speculative_k=2)


# ----------------------------------------------------------------------
# proposer units
# ----------------------------------------------------------------------
def test_ngram_proposer_longest_most_recent_match():
    p = NGramProposer(max_ngram=3, min_ngram=1)
    #          0  1  2  3  4  5  6  7
    ctx = [5, 6, 7, 9, 5, 6, 7, 9]          # no: suffix (7,9) -> after idx 2
    # suffix trigram (6,7,9) occurs at 1..3; continuation is ctx[4:] = 5,6,7
    got = p.propose(np.array(ctx, np.int32), 3)
    assert got.tolist() == [5, 6, 7]


def test_ngram_proposer_prefers_recent_occurrence():
    p = NGramProposer(max_ngram=2, min_ngram=1)
    ctx = np.array([1, 2, 9, 1, 2, 4, 1, 2], np.int32)
    # suffix (1, 2): most recent earlier occurrence at 3 -> proposes 4, 1
    assert p.propose(ctx, 2).tolist() == [4, 1]


def test_ngram_proposer_no_match_and_truncation():
    p = NGramProposer(max_ngram=3, min_ngram=1)
    assert p.propose(np.array([1, 2, 3], np.int32), 4).tolist() == []
    # match at the very end proposes fewer than k tokens
    assert p.propose(np.array([7, 7], np.int32), 4).tolist() == [7]
    assert p.propose(np.array([5], np.int32), 4).tolist() == []


def test_make_proposer_unknown_policy():
    with pytest.raises(ValueError, match="unknown speculative_policy"):
        make_proposer("tree-of-drafts")


def test_greedy_verify_exact_match_prefix():
    V = 8
    logits = np.full((4, V), -1.0)
    logits[0, 3] = 1.0                        # greedy: 3
    logits[1, 5] = 1.0                        # greedy: 5
    logits[2, 2] = 1.0                        # greedy: 2
    n, nxt = greedy_verify(logits, [3, 5, 7])
    assert (n, nxt) == (2, 2)                 # 7 rejected -> row 2's argmax
    n, nxt = greedy_verify(logits, [])
    assert (n, nxt) == (0, 3)


# ----------------------------------------------------------------------
# rejection sampling: distribution identity with the base sampler
# ----------------------------------------------------------------------
N_DRAWS = 4000


def _committed_dist(logits, draft, **kw):
    """Empirical distribution of the first committed token when ``draft``
    is proposed at the position (accept-or-resample)."""
    rng = np.random.default_rng(0)
    row = np.asarray(logits, np.float64)
    rows = np.tile(row[None], (2, 1))         # bonus row for the accept case
    counts = {}
    for _ in range(N_DRAWS):
        n_acc, nxt = rejection_verify(rows, [draft], rng, **kw)
        tok = draft if n_acc == 1 else nxt
        counts[tok] = counts.get(tok, 0) + 1
    return {t: c / N_DRAWS for t, c in counts.items()}


def _base_dist(logits, **kw):
    ids, p = truncated_probs_np(np.asarray(logits, np.float64),
                                temperature=kw["temperature"],
                                top_k=kw.get("top_k", 0),
                                top_p=kw.get("top_p", 1.0))
    return {int(t): float(pp) for t, pp in zip(ids, p)}


def _assert_dist_close(emp, ref, tol=0.035):
    assert set(emp) <= set(ref)               # support never leaks
    for t, p in ref.items():
        assert abs(emp.get(t, 0.0) - p) < tol, (t, emp.get(t, 0.0), p)


@pytest.mark.parametrize("kw", [
    dict(temperature=1.0, top_k=4),
    dict(temperature=0.7, top_p=0.6),
    dict(temperature=1.0, top_k=6, top_p=0.5),
])
def test_rejection_sampler_matches_base_distribution_tie_heavy(kw):
    """Tie-heavy logits straddling the top-k / nucleus boundary — exactly
    where the twins harness pins the candidate sets — with an in-support
    draft, an out-of-support draft, and a no-draft bonus: the committed
    token's distribution must match the truncated base sampler's."""
    logits = np.array([0., 1.] * 8)           # ties on odd indices
    ref = _base_dist(logits, **kw)
    for draft in (1, 0):                      # in-support tie / out-of-support
        emp = _committed_dist(logits, draft, **kw)
        _assert_dist_close(emp, ref)


def test_rejection_sampler_matches_base_distribution_generic():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=24)
    kw = dict(temperature=1.3, top_p=0.8)
    ref = _base_dist(logits, **kw)
    draft = max(ref, key=ref.get)             # the draft a proposer would hit
    _assert_dist_close(_committed_dist(logits, draft, **kw), ref)
    # bonus-token path (no drafts) must be the base draw itself
    rng_a, rng_b = np.random.default_rng(5), np.random.default_rng(5)
    for _ in range(64):
        _, nxt = rejection_verify(np.asarray(logits)[None], [], rng_a, **kw)
        assert nxt == sample_np(logits, rng_b, **kw)


def test_sampled_speculative_engine_runs_and_reports():
    """Sampling + speculation end-to-end: the engine commits via the
    rejection sampler and reports acceptance metrics (stream equality is
    not expected — the committed distribution is, tested above)."""
    eng = _build(3, gen=12, temperature=0.8, top_k=12)
    outs, rep = captured_run(eng, _repetitive_requests(4, gen=12))
    assert all(len(t) > 0 for t in outs.values())
    sp = rep["speculative"]
    assert sp["steps"] > 0 and sp["committed_tokens"] >= sp["steps"]
