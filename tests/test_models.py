"""Per-arch smoke tests: every assigned architecture (+ the paper's own
models) instantiates a REDUCED same-family config and runs one train step and
a prefill + 2 decode steps on CPU, asserting shapes and finiteness.

Also checks the prefill/decode consistency invariant: prefill(S+1) last
logits == prefill(S) + decode(1) logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, SHAPES, get_config, iter_cells, shape_applicable
from repro.configs.base import ParallelConfig
from repro.models.model import MeshShape, build_model
from repro.launch.mesh import make_mesh

ARCHS = sorted(REGISTRY)


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _batch_for(cfg, B, S, train=True):
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    if train:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.float32)
    if cfg.num_prefix_embeddings:
        batch["patches"] = jnp.ones((B, cfg.num_prefix_embeddings, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train(arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 16
    mesh = _mesh()
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=B, seq_len=S, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    with mesh:
        loss, diags = jax.jit(model.train_loss)(params, _batch_for(cfg, B, S))
    assert np.isfinite(float(loss)), arch
    # random-init loss should be near ln(vocab)
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    B, S = 2, 16
    mesh = _mesh()
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=B, seq_len=S, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B, S, train=False)
    with mesh:
        logits, caches, pos, _ = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=S + 8))(params, batch)
        assert logits.shape[0] == B
        assert bool(jnp.isfinite(logits).all()), arch
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(2):
            logits, caches, pos, _ = jax.jit(model.decode_step)(
                params, tok, caches, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma2-2b",
                                  "mixtral-8x7b", "mamba2-2.7b", "zamba2-7b"])
def test_prefill_decode_consistency(arch):
    """prefill(S+1).logits == (prefill(S) then decode(token S+1)).logits."""
    cfg = get_config(arch).reduced().replace(dtype="float32")
    B, S = 1, 12
    mesh = _mesh()
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=B, seq_len=S + 1, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size).astype(jnp.int32)
    with mesh:
        full, _, _, _ = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=S + 8))(
            params, {"tokens": toks})
        part, caches, pos, _ = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=S + 8))(
            params, {"tokens": toks[:, :S]})
        step, _, _, _ = jax.jit(model.decode_step)(
            params, toks[:, S:S + 1], caches, pos)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_cell_matrix_accounting():
    """The assignment matrix is 10 archs x 4 shapes = 40 cells; skips are only
    the documented long_500k full-attention exclusions (DESIGN.md §5)."""
    cells = list(iter_cells(include_skipped=True))
    assert len(cells) == 40
    skipped = [(a, s.name) for a, s, ok, _ in cells if not ok]
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, s, ok, _ in cells
                     if s.name == "long_500k" and ok]
    assert sorted(runnable_long) == ["mamba2-2.7b", "mixtral-8x7b",
                                     "zamba2-7b"]
    assert len(cells) - len(skipped) == 33
