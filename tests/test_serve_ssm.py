"""SSM / hybrid serving through the SequenceStateStore protocol.

The ISSUE-mandated invariants for the slotted state pool
(``serve/statestore.SlotStateStore``):

* greedy streams through ``ServeEngine`` are token-identical to the
  one-shot prefill + lockstep-decode oracle for a pure-SSM (mamba2) and a
  hybrid (zamba2-style) reduced config, including partial final prefill
  chunks (prompt lengths not multiples of the chunk);
* prefill-continuation carry is isolated per request: the batch-1
  recurrent scratch is reset at every ``begin_prefill``, so back-to-back
  requests through one slot never inherit state;
* preemption resume is token-exact: dropping a slot's recurrent state and
  re-prefilling prompt + committed output reproduces the stream;
* slot recycling never recompiles (one jit entry per step fn, warmup
  covers them, ``recompiled_after_warmup`` is False);
* ``report()["state_pool"]`` carries the slot-store section;
* ``paged=True`` is rejected loudly for recurrent-state families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.models.model import build_model
from repro.serve import (EngineConfig, Request, ServeEngine, SlotStateStore,
                         VirtualClock, engine_config_for, make_state_store)

from _serve_helpers import captured_run

L_MAX, GEN, CHUNK = 13, 6, 4          # 13 = 4 + 4 + 4 + 1: partial chunk


def _build(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=1, seq_len=L_MAX)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mamba():
    return _build("mamba2-2.7b")


@pytest.fixture(scope="module")
def zamba():
    return _build("zamba2-7b")


def _engine(cfg, model, params, *, slots=2):
    ecfg = engine_config_for(cfg, max_slots=slots, prompt_len=L_MAX,
                             max_new_tokens=GEN, prefill_chunk=CHUNK)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.5))


def _oracle(model, params, prompt, s_max, gen=GEN):
    logits, caches, pos, _ = model.prefill(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])},
        s_max=s_max)
    out = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(gen - 1):
        logits, caches, pos, _ = model.decode_step(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


# ----------------------------------------------------------------------
# token identity vs the one-shot oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba", "zamba"])
def test_engine_matches_one_shot(family, request):
    """Chunked prefill + slotted decode == one-shot, token for token, for
    concurrent requests with non-chunk-multiple prompt lengths (partial
    final chunks exercise the pad-masked SSD update)."""
    cfg, model, params = request.getfixturevalue(family)
    eng = _engine(cfg, model, params)
    prompts = _prompts(cfg, (13, 9, 7))
    outputs, rep = captured_run(
        eng, [Request(rid=i, tokens=p, max_new_tokens=GEN)
              for i, p in enumerate(prompts)])
    assert isinstance(eng.kv, SlotStateStore)
    for i, p in enumerate(prompts):
        assert outputs[i] == _oracle(model, params, p,
                                     eng.ecfg.max_seq_len), f"rid {i}"
    assert rep["state_pool"]["kind"] == "slot"


def test_scratch_reset_between_requests(mamba):
    """Two requests through ONE slot, back to back: the second stream
    must match its solo oracle — recurrent prefill state carried across
    chunk calls for request A must never leak into request B (the
    begin_prefill scratch reset)."""
    cfg, model, params = mamba
    eng = _engine(cfg, model, params, slots=1)
    prompts = _prompts(cfg, (13, 11), seed=7)
    outputs, rep = captured_run(
        eng, [Request(rid=i, tokens=p, max_new_tokens=GEN)
              for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        assert outputs[i] == _oracle(model, params, p,
                                     eng.ecfg.max_seq_len), f"rid {i}"
    # one reset per prefill pickup (plus warmupless run => exactly 2)
    assert rep["state_pool"]["scratch_resets"] == 2


# ----------------------------------------------------------------------
# preemption resume
# ----------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba", "zamba"])
def test_preemption_resume_token_exact(family, request):
    """Preempt mid-decode (recurrent state dropped), resume, and the
    full stream is identical: re-prefilling prompt + committed output
    reproduces the SSD fold token-exactly."""
    cfg, model, params = request.getfixturevalue(family)
    eng = _engine(cfg, model, params)
    [prompt] = _prompts(cfg, (13,), seed=11)

    base_out, _ = captured_run(
        eng, [Request(rid=0, tokens=prompt, max_new_tokens=GEN)])

    eng2 = _engine(cfg, model, params)
    outputs = {}
    orig = eng2._finish

    def cap(st, now):
        outputs[st.req.rid] = list(st.output)
        orig(st, now)

    eng2._finish = cap
    eng2.submit(Request(rid=0, tokens=prompt, max_new_tokens=GEN))
    preempted = False
    while eng2.has_work():
        eng2.step(eng2.clock.now())
        if not preempted and eng2.active.any():
            s = int(np.nonzero(eng2.active)[0][0])
            st = eng2.state_by_slot[s]
            if st is not None and len(st.output) >= 3:
                eng2._preempt(st)
                preempted = True
    assert preempted and eng2.metrics.preemptions == 1
    assert outputs[0] == base_out[0]
    assert eng2.report()["state_pool"]["preemptions"] == 1


# ----------------------------------------------------------------------
# compile stability
# ----------------------------------------------------------------------
def test_zero_post_warmup_recompiles(zamba):
    cfg, model, params = zamba
    eng = _engine(cfg, model, params)
    eng.warmup()
    prompts = _prompts(cfg, (13, 9, 11, 7), seed=5)
    rep = eng.run([Request(rid=i, tokens=p, max_new_tokens=GEN)
                   for i, p in enumerate(prompts)])
    assert rep["recompiled_after_warmup"] is False
    assert rep["jit_entries"]["decode"] == 1


# ----------------------------------------------------------------------
# store selection + protocol edges
# ----------------------------------------------------------------------
@pytest.mark.parametrize("family", ["mamba", "zamba"])
def test_paged_rejected_for_recurrent_state(family, request):
    cfg, model, params = request.getfixturevalue(family)
    ecfg = EngineConfig(max_slots=2, max_seq_len=32, prefill_chunk=4,
                        paged=True)
    with pytest.raises(ValueError, match="recurrent"):
        ServeEngine(model, params, ecfg, clock=VirtualClock())


def test_slot_store_protocol_surface(mamba):
    cfg, model, params = mamba
    ecfg = EngineConfig(max_slots=2, max_seq_len=32, prefill_chunk=4)
    store = make_state_store(model, ecfg, s_pad=32, ctx=_null_ctx)
    assert isinstance(store, SlotStateStore)
    assert not store.paged and not store.sharing
    assert store.kv_capacity == ecfg.max_seq_len   # no KV-length axis
    assert store.share_plan([1, 2, 3], resumed=False) == (0, [], 0, False)
    assert store.can_admit((0, [], 0, False))
    store.release(rid=0, slot=0)                   # no-op, must not raise
    assert store.probe_prefix([1, 2, 3]) == 0
    with pytest.raises(RuntimeError):
        store.bt_row(0)
    with pytest.raises(NotImplementedError):
        store.export_kv(8)
    with pytest.raises(NotImplementedError):
        store.import_kv([], 8, None)
    stats = store.stats()
    assert stats["kind"] == "slot" and stats["slots"] == 2
    assert stats["pool_bytes"] > 0 and stats["state_bytes_per_slot"] > 0


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
