"""Scheduler unit + property tests (paper Alg. 2 invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.scheduler import (even_split, initial_assign, rebalance,
                                  schedule)
from repro.core.topology import make_topology, static_opt_placement

jax.config.update("jax_platform_name", "cpu")


def test_paper_fig6_example():
    """3 GPUs, 15 tokens, expert loads (2, 4, 9) -> perfectly balanced 5/5/5
    (paper Figure 6)."""
    topo = make_topology(3, 3)
    counts = jnp.array([[1, 2, 2], [1, 1, 3], [0, 1, 4]], jnp.int32)
    S, diag = schedule(counts, topo, policy="harmoeny", q=1, c_pair=100,
                       num_foreign_slots=2)
    t_g = np.asarray(S.sum(axis=(0, 1)))
    assert t_g.tolist() == [5, 5, 5]
    assert (np.asarray(S.sum(axis=2)) == np.asarray(counts)).all()


def test_initial_assign_routes_to_hosts():
    topo = make_topology(4, 8)
    counts = jnp.full((4, 8), 3, jnp.int32)
    S = initial_assign(counts, topo)
    for e in range(8):
        host = int(topo.host_of[e, 0])
        assert int(S[:, e, host].sum()) == 12
        assert int(S[:, e, :].sum()) == 12


def test_initial_assign_replicated_split():
    """E < G: token load splits across an expert's host replicas."""
    topo = make_topology(4, 2)
    counts = jnp.array([[5, 0], [0, 0], [0, 0], [0, 0]], jnp.int32)
    S = initial_assign(counts, topo)
    hosts = topo.host_of[0]
    assert int(S[0, 0, hosts[0]]) == 3  # ceil split
    assert int(S[0, 0, hosts[1]]) == 2


def test_heavy_skew_balances():
    """90%-skew (paper §5.2): max load drops to ~average."""
    topo = make_topology(16, 64)
    counts = jnp.full((16, 64), 2, jnp.int32).at[:, 0].set(1000)
    S, diag = schedule(counts, topo, policy="harmoeny", q=4, c_pair=200,
                       num_foreign_slots=4)
    t_g = np.asarray(S.sum(axis=(0, 1)))
    avg = int(counts.sum()) // 16
    assert t_g.max() <= avg + 4
    assert int(diag.max_load_before) > 10 * int(diag.max_load_after)


def test_round_robin_keeps_initial():
    topo = make_topology(4, 8)
    counts = jnp.full((4, 8), 3, jnp.int32).at[0, 0].set(50)
    S, _ = schedule(counts, topo, policy="round_robin", q=1, c_pair=100,
                    num_foreign_slots=2)
    assert (np.asarray(S) == np.asarray(initial_assign(counts, topo))).all()


def test_even_split_uniform():
    topo = make_topology(4, 8)
    counts = jnp.full((4, 8), 8, jnp.int32)
    S = even_split(counts, topo)
    t_g = np.asarray(S.sum(axis=(0, 1)))
    assert (t_g == t_g[0]).all()
    assert (np.asarray(S.sum(axis=2)) == np.asarray(counts)).all()


def test_q_threshold_stops_small_moves():
    """Moves below q are not worth an expert fetch (paper Eq. 4)."""
    topo = make_topology(4, 8)
    counts = jnp.full((4, 8), 1, jnp.int32).at[0, 0].set(4)
    S, diag = schedule(counts, topo, policy="harmoeny", q=1000, c_pair=1000,
                       num_foreign_slots=2)
    assert int(diag.moved) == 0


def test_foreign_slot_budget():
    """No destination hosts more than K distinct non-resident experts."""
    topo = make_topology(4, 8)
    counts = jnp.zeros((4, 8), jnp.int32).at[:, :4].set(100)
    K = 1
    S, _ = schedule(counts, topo, policy="harmoeny", q=1, c_pair=1000,
                    num_foreign_slots=K)
    from repro.core.topology import local_slot_of
    lsl = local_slot_of(topo)
    S_np = np.asarray(S)
    for g in range(4):
        foreign = sum(1 for e in range(8)
                      if S_np[:, e, g].sum() > 0 and lsl[g, e] < 0)
        assert foreign <= K, (g, foreign)


def test_static_opt_placement_spreads_hot_experts():
    profile = np.array([100, 90, 80, 70, 1, 1, 1, 1], np.float64)
    perm = static_opt_placement(profile, 4)
    topo = make_topology(4, 8, placement=perm)
    hot_hosts = {int(topo.host_of[e, 0]) for e in range(4)}
    assert len(hot_hosts) == 4  # the four hot experts land on four ranks


# ----------------------------------------------------------------------
# Property-based invariants
# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([(4, 8), (4, 4), (8, 16)]),
       st.integers(1, 8), st.booleans())
def test_rebalance_properties(seed, gsh, q, skew):
    G, E = gsh
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 20, (G, E)).astype(np.int32)
    if skew:
        counts[:, 0] += rng.integers(50, 200)
    counts = jnp.asarray(counts)
    topo = make_topology(G, E)
    c_pair = max(int(2 * counts.sum()) // (G * G), 8)
    S0 = initial_assign(counts, topo)
    S, diag = rebalance(S0, topo, q=q, c_pair=c_pair, num_foreign_slots=4)
    S_np, S0_np = np.asarray(S), np.asarray(S0)
    # 1. conservation: scheduling never creates or destroys units
    assert (S_np.sum(axis=2) == np.asarray(counts)).all()
    # 2. non-negative
    assert (S_np >= 0).all()
    # 3. max destination load never increases
    assert S_np.sum(axis=(0, 1)).max() <= S0_np.sum(axis=(0, 1)).max()
    # 4. deterministic (replicated scheduling relies on this)
    S2, _ = rebalance(S0, topo, q=q, c_pair=c_pair, num_foreign_slots=4)
    assert (np.asarray(S2) == S_np).all()


def test_extra_local_spreads_without_foreign_slots():
    """A hot expert replicated on every rank (extra_local) can shed load to
    all of them with ZERO foreign slots — replica slots are weight-resident
    destinations, exactly like the expert's host."""
    topo = make_topology(4, 8)
    counts = jnp.zeros((4, 8), jnp.int32).at[:, 0].set(100)
    # without replication and K=0, nothing can move off expert 0's host
    S_none, d_none = schedule(counts, topo, policy="harmoeny", q=1,
                              c_pair=1000, num_foreign_slots=0)
    assert int(d_none.moved) == 0
    extra = jnp.zeros((4, 8), bool).at[:, 0].set(True)
    S, diag = schedule(counts, topo, policy="harmoeny", q=1, c_pair=1000,
                       num_foreign_slots=0, extra_local=extra)
    t_g = np.asarray(S.sum(axis=(0, 1)))
    assert t_g.tolist() == [100, 100, 100, 100]
    assert (np.asarray(S.sum(axis=2)) == np.asarray(counts)).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([(4, 8), (8, 16)]),
       st.integers(1, 8), st.integers(0, 2))
def test_rebalance_extra_local_properties(seed, gsh, q, n_rep):
    """Alg. 2 invariants hold with replica-slot placements mixed in: the
    schedule stays conserved, non-negative, deterministic, and no worse
    than without the extra placements."""
    G, E = gsh
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 20, (G, E)).astype(np.int32)
    counts[:, 0] += rng.integers(50, 200)
    counts = jnp.asarray(counts)
    topo = make_topology(G, E)
    # replicate the n_rep hottest experts on every rank (a superset of any
    # real placement: hosts included — is_local is already True there)
    extra = np.zeros((G, E), bool)
    extra[:, :n_rep] = True
    extra = jnp.asarray(extra)
    c_pair = max(int(2 * counts.sum()) // (G * G), 8)
    S0 = initial_assign(counts, topo)
    S, _ = rebalance(S0, topo, q=q, c_pair=c_pair, num_foreign_slots=2,
                     extra_local=extra)
    S_np, S0_np = np.asarray(S), np.asarray(S0)
    assert (S_np.sum(axis=2) == np.asarray(counts)).all()
    assert (S_np >= 0).all()
    assert S_np.sum(axis=(0, 1)).max() <= S0_np.sum(axis=(0, 1)).max()
    S_plain, _ = rebalance(S0, topo, q=q, c_pair=c_pair, num_foreign_slots=2)
    # replication can only help: the balanced max load is no worse
    assert S_np.sum(axis=(0, 1)).max() \
        <= np.asarray(S_plain).sum(axis=(0, 1)).max()
    S2, _ = rebalance(S0, topo, q=q, c_pair=c_pair, num_foreign_slots=2,
                      extra_local=extra)
    assert (np.asarray(S2) == S_np).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_even_split_conservation(seed):
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, 50, (4, 8)).astype(np.int32))
    topo = make_topology(4, 8)
    S = even_split(counts, topo)
    assert (np.asarray(S.sum(axis=2)) == np.asarray(counts)).all()
    t_g = np.asarray(S.sum(axis=(0, 1)))
    # remainders always land on the lowest-index ranks: worst-case spread is
    # one unit per (src, expert) pair
    assert t_g.max() - t_g.min() <= 4 * 8
