"""End-to-end HarMoEny MoE block vs dense oracle (single device, EP=1),
policy behaviour, and gradient flow. Multi-device parity lives in
tests/test_distributed.py (subprocess with 8 fake devices)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.moe_layer import MoEBlockSpec, init_moe_params, moe_block
from repro.core.router import route_topk
from repro.core.topology import make_topology
from repro.launch.mesh import make_mesh


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _dense_oracle(x, params, E, k, act="silu"):
    d = x.shape[-1]
    flat = np.asarray(x).reshape(-1, d)
    r = route_topk(jnp.asarray(flat), params["router"], top_k=k,
                   num_real_experts=E)
    y = np.zeros_like(flat)
    for t in range(flat.shape[0]):
        for j in range(k):
            e = int(r.assign[t, j])
            g = float(r.gates[t, j])
            h = flat[t] @ np.asarray(params["w_in"][e])
            if "w_gate" in params:
                h = np.asarray(jax.nn.silu(flat[t] @ params["w_gate"][e])) * h
            else:
                h = np.asarray(jax.nn.gelu(h))
            y[t] += g * (h @ np.asarray(params["w_out"][e]))
    return y.reshape(x.shape)


@pytest.mark.parametrize("policy", ["harmoeny", "round_robin", "even_split"])
def test_moe_block_matches_oracle_ep1(policy):
    B, S, d, f, E, k = 2, 16, 16, 32, 4, 2
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    policy=policy, capacity_factor=2.0,
                    num_foreign_slots=E if policy == "even_split" else 2)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model", batch_axes=(),
                        ep_degree=1, tokens_local=B * S, block_m=8, act="silu")
    mesh = _mesh11()
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y, diag = jax.jit(
            lambda x, p: moe_block(x, p, spec=spec, mesh=mesh))(x, params)
    y_ref = _dense_oracle(x, params, E, k)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)
    assert float(diag["send_drops"].sum() + diag["dest_drops"].sum()) == 0


def test_tp_mode_matches_oracle():
    B, S, d, f, E, k = 2, 8, 16, 32, 2, 1
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model", batch_axes=(),
                        ep_degree=1, tokens_local=B * S, block_m=8,
                        act="silu", tp_mode=True)
    mesh = _mesh11()
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y, _ = jax.jit(
            lambda x, p: moe_block(x, p, spec=spec, mesh=mesh))(x, params)
    y_ref = _dense_oracle(x, params, E, k)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)


def test_gradients_flow_and_finite():
    B, S, d, f, E, k = 2, 16, 16, 32, 4, 2
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    capacity_factor=2.0, num_foreign_slots=2)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model", batch_axes=(),
                        ep_degree=1, tokens_local=B * S, block_m=8, act="silu")
    mesh = _mesh11()
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

    def loss(p):
        y, _ = moe_block(x, p, spec=spec, mesh=mesh)
        return (y ** 2).mean()

    with mesh:
        g = jax.jit(jax.grad(loss))(params)
    for name in ("w_in", "w_out", "w_gate", "router"):
        n = float(jnp.linalg.norm(g[name]))
        assert np.isfinite(n), name
        if name != "router":
            assert n > 0, name


def test_skewed_router_rebalances():
    """Synthetic 90% skew (paper §5.1.2): scheduler moves load, no drops."""
    B, S, d, f, E, k = 2, 64, 16, 32, 8, 2
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    router_skew=0.9, q_tokens=2, capacity_factor=1.5,
                    num_foreign_slots=4)
    # EP=1 has a single rank -> schedule trivially balanced; just verify the
    # path runs and counts stay consistent (true multi-rank balance checked
    # in test_distributed.py).
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model", batch_axes=(),
                        ep_degree=1, tokens_local=B * S, block_m=8, act="silu")
    mesh = _mesh11()
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y, diag = jax.jit(lambda x, p: moe_block(
            x, p, spec=spec, mesh=mesh,
            skew_key=jax.random.PRNGKey(3)))(x, params)
    assert bool(jnp.isfinite(y).all())
    assert float(diag["send_drops"].sum() + diag["dest_drops"].sum()) == 0
