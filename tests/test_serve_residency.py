"""Tiered expert-residency serving differential (multi-device subprocess,
like test_serve_rebalance.py):

* greedy token streams are *identical* across a fully-resident budget, a
  tight budget under every prefetch policy, and residency off — device
  parameters stay authoritative, so the tier emulation moves scheduling
  and accounting, never math;
* the ``[G, W]`` residency table rides into the decode jit entry as a
  traced argument: the decode cache holds ONE entry and nothing
  recompiles after warmup, across live working-set swaps;
* the same holds under prefix sharing + speculative k=4 (the verify-step
  decode path shares the residency threading);
* ``report()["residency"]`` is populated (hit_rate, stall_units, swaps,
  bytes_staged) and the engine-level config validation rejects bad
  budgets and unknown policies.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


_COMMON = """
import numpy as np, jax
from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.models.model import MeshShape, build_model
from repro.serve import (Request, ServeEngine, VirtualClock,
                         engine_config_for)

CFG = ModelConfig(
    name="tinymoe", family="moe", num_layers=2, d_model=32,
    num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
    head_dim=16, dtype="float32",
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_ff_expert=32,
                  policy="harmoeny", router_skew=0.95, q_tokens=1,
                  num_foreign_slots=2))
MESH = make_host_mesh(1, 4)
MS = MeshShape(tuple(zip(MESH.axis_names, MESH.devices.shape)))
MODEL = build_model(CFG, ParallelConfig(attn_chunk=8, loss_chunk=8),
                    batch=4, seq_len=16, mesh_shape=MS, mesh=MESH)
with MESH:
    PARAMS = MODEL.init(jax.random.PRNGKey(0))


def requests(shared_prefix=0):
    rng = np.random.default_rng(7)
    pre = rng.integers(1, 60, size=shared_prefix).astype(np.int32)
    out = []
    for i in range(6):
        toks = rng.integers(1, 60, size=8).astype(np.int32)
        toks[:shared_prefix] = pre
        out.append(Request(rid=i, tokens=toks, max_new_tokens=6,
                           arrival_time=0.0))
    return out


def run_engine(resident, policy, shared_prefix=0, **ekw):
    ecfg = engine_config_for(CFG, max_slots=4, prompt_len=8,
                             max_new_tokens=6, prefill_chunk=4,
                             resident_experts=resident,
                             prefetch_policy=policy, **ekw)
    eng = ServeEngine(MODEL, PARAMS, ecfg, mesh=MESH,
                      clock=VirtualClock(0.5))
    eng.warmup()
    # capture every finished request's exact greedy token stream
    tokens = {}
    orig = eng._finish
    def capture(st, now):
        tokens[st.req.rid] = list(st.output)
        orig(st, now)
    eng._finish = capture
    rep = eng.run(requests(shared_prefix))
    return rep, tokens
"""


def test_residency_budgets_token_identical_and_jit_stable():
    """Plain-decode differential over the same skewed request stream:
    residency off / fully resident / tight budget x {predictive,
    on_demand, none} all produce bit-identical greedy token streams with
    one decode jit entry and zero post-warmup recompiles, while the
    tight budgets actually swap (staging scatters dispatched) and report
    a populated residency section."""
    _run(_COMMON + """
    cells = {
        "off":  run_engine(0, "predictive"),
        "full": run_engine(8, "predictive"),
        "pred": run_engine(4, "predictive"),
        "odem": run_engine(4, "on_demand"),
        "none": run_engine(4, "none"),
    }
    base = cells["off"][1]
    assert base and all(len(v) for v in base.values())
    for name, (rep, toks) in cells.items():
        assert toks == base, f"{name} diverged from residency-off"
        lb = rep["load_balance"]["decode"]
        assert lb["send_drops_total"] == 0, name
        assert lb["dest_drops_total"] == 0, name
        assert rep["jit_entries"]["decode"] == 1, name
        assert rep["recompiled_after_warmup"] is False, name

    # residency section populated, hits+misses == lookups
    for name in ("full", "pred", "odem", "none"):
        res = cells[name][0]["residency"]
        assert res["lookups"] > 0, name
        assert res["hits"] + res["misses"] == res["lookups"], name
    full = cells["full"][0]["residency"]
    assert full["hit_rate"] == 1.0 and full["swaps"] == 0

    # tight budgets miss and (except under "none") stage weights in
    pred_rep, odem_rep = cells["pred"][0], cells["odem"][0]
    for rep in (pred_rep, odem_rep):
        res = rep["residency"]
        assert res["swaps"] >= 1 and res["bytes_staged"] > 0
        assert rep["engine"]["residency_stages"] >= 1
        assert rep["jit_entries"]["residency_stage"] >= 1
    none_res = cells["none"][0]["residency"]
    assert none_res["swaps"] == 0 and none_res["bytes_staged"] == 0
    assert none_res["stall_units"] >= odem_rep["residency"]["stall_units"]
    assert cells["pred"][0]["residency"]["prefetches"] >= 1
    print("OK")
    """)


def test_residency_under_prefix_sharing_and_speculation():
    """The verify-step decode path (paged + prefix sharing + k=4
    self-drafting) threads the same residency table: tight-budget
    predictive stays token-identical to residency off, with one decode
    jit entry and no post-warmup recompiles across swaps."""
    _run(_COMMON + """
    kw = dict(paged=True, kv_block_size=4, prefix_sharing=True,
              speculative_k=4)
    off_rep, off_toks = run_engine(0, "predictive", shared_prefix=4, **kw)
    res_rep, res_toks = run_engine(4, "predictive", shared_prefix=4, **kw)
    assert off_toks and res_toks == off_toks, "residency diverged the stream"
    for name, rep in (("off", off_rep), ("res", res_rep)):
        assert rep["jit_entries"]["decode"] == 1, name
        assert rep["recompiled_after_warmup"] is False, name
        lb = rep["load_balance"]["decode"]
        assert lb["send_drops_total"] == 0, name
        assert lb["dest_drops_total"] == 0, name
    res = res_rep["residency"]
    assert res["lookups"] > 0
    assert res["hits"] + res["misses"] == res["lookups"]
    assert res_rep["engine"]["prefetch_policy"] == "predictive"
    # prefix sharing still worked under residency
    assert res_rep["prefix_hit_rate"] and res_rep["prefix_hit_rate"] > 0
    print("OK")
    """)


def test_engine_rejects_bad_residency_budget():
    """Budgets that don't split across the EP degree — or exceed the
    pod's expert rows — are admission-time errors, not silent clamps."""
    _run(_COMMON + """
    for bad in (3, 12):     # not a multiple of G=4; > 8 pod expert rows
        ecfg = engine_config_for(CFG, max_slots=4, prompt_len=8,
                                 max_new_tokens=6, prefill_chunk=4,
                                 resident_experts=bad)
        try:
            ServeEngine(MODEL, PARAMS, ecfg, mesh=MESH)
        except ValueError as e:
            assert "resident_experts" in str(e), e
        else:
            raise AssertionError(f"budget {bad} was accepted")
    print("OK")
    """)


def test_engine_config_validation():
    from repro.serve.engine import EngineConfig
    with pytest.raises(ValueError):
        EngineConfig(resident_experts=-1)
    with pytest.raises(ValueError):
        EngineConfig(prefetch_policy="psychic")
    EngineConfig(resident_experts=8, prefetch_policy="on_demand")  # valid


def test_residency_needs_moe():
    from repro.serve.metrics import ServeMetrics
    m = ServeMetrics()
    assert "residency" not in m.report()       # off => section absent
    m.residency = {"hits": 1, "lookups": 1, "hit_rate": 1.0}
    assert m.report()["residency"]["hit_rate"] == 1.0
