"""Shared helpers for the serve-engine test suites."""


def captured_run(eng, reqs):
    """Run the engine while capturing each request's emitted token stream
    (hooked at ``_finish``, before slot state is recycled).  Returns
    ({rid: [tokens]}, report)."""
    outputs = {}
    orig = eng._finish

    def capture(st, now):
        outputs[st.req.rid] = list(st.output)
        orig(st, now)

    eng._finish = capture
    rep = eng.run(reqs)
    return outputs, rep
