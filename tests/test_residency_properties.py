"""Property-based fuzz of the tiered expert-residency bookkeeping.

Random interleavings of lookup/stage/evict/pin/unpin against
``ResidencyCache`` must preserve, after every single operation:

* budget — the working set never exceeds ``capacity``;
* pinning — a pinned (current-layer) expert is never evicted, neither
  explicitly (``evict`` returns False) nor by staging pressure (``stage``
  picks the least-recent *unpinned* victim, or refuses with None when
  every slot is pinned);
* accounting — ``hits + misses == lookups`` and the eviction/stage
  counters move in lockstep with the observed transitions;
* order — evictions take the least-recently-used unpinned expert.

A second program fuzzes ``ExpertResidencyManager.step`` with random
per-layer load matrices and checks the decision-level invariants: the
``[G, W]`` table stays within each rank's own shard with unique ids,
stage rows index real weight rows, policy ``none`` never stages, and a
fully-resident budget never misses.

Runs under real ``hypothesis`` when installed (derandomized) and under
``tests/_hypothesis_shim.py`` otherwise — coverage is deterministic
either way.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.topology import EPTopology, make_topology
from repro.serve.residency import (ExpertResidencyManager, ResidencyCache,
                                   TierCostModel)


# ----------------------------------------------------------------------
# ResidencyCache op-program fuzz
# ----------------------------------------------------------------------
def check_cache(c: ResidencyCache) -> None:
    res = c.resident
    assert len(res) == len(set(res)) <= c.capacity
    assert set(res) <= set(c.eligible)
    assert c.hits + c.misses == c.lookups
    assert c.pinned <= frozenset(c.eligible)


def run_cache_program(seed: int, *, n_ops: int = 80) -> ResidencyCache:
    rng = random.Random(seed)
    shard = list(range(rng.randint(2, 10)))
    cap = rng.randint(1, len(shard))
    c = ResidencyCache(cap, shard)
    foreign = max(shard) + 1

    for _ in range(n_ops):
        op = rng.choice(["lookup", "lookup", "stage", "stage", "evict",
                         "pin", "unpin", "foreign"])
        before = c.resident                    # LRU order snapshot
        pinned = set(c.pinned)
        counters = (c.hits, c.misses, c.lookups, c.evictions, c.stages)
        e = rng.choice(shard)
        if op == "lookup":
            hit = c.lookup(e)
            assert hit == (e in before)
            if hit:
                assert c.resident[-1] == e     # refreshed to most-recent
                assert c.hits == counters[0] + 1
            else:
                assert c.misses == counters[1] + 1
            assert c.lookups == counters[2] + 1
        elif op == "stage":
            out = c.stage(e)
            if e in before:
                assert out == -1               # refresh, nothing evicted
                assert set(c.resident) == set(before)
            elif len(before) < c.capacity:
                assert out == -1
                assert set(c.resident) == set(before) | {e}
            else:
                victims = [v for v in before if v not in pinned]
                if not victims:
                    assert out is None         # all pinned: refused
                    assert c.resident == before
                else:
                    assert out == victims[0]   # least-recent unpinned
                    assert out not in c.resident
                    assert c.evictions == counters[3] + 1
                    assert set(c.resident) == \
                        (set(before) - {out}) | {e}
        elif op == "evict":
            ok = c.evict(e)
            assert ok == (e in before and e not in pinned)
            if ok:
                assert e not in c.resident
                assert c.evictions == counters[3] + 1
            else:
                assert c.resident == before
        elif op == "pin":
            sub = rng.sample(shard, rng.randint(0, len(shard)))
            c.pin(sub)
            assert c.pinned == frozenset(sub)
        elif op == "unpin":
            c.unpin()
            assert c.pinned == frozenset()
        elif op == "foreign":
            with pytest.raises(KeyError):
                c.lookup(foreign)
            with pytest.raises(KeyError):
                c.stage(foreign)
            assert c.lookups == counters[2]    # foreign ids never counted
        # pinned residents survive every operation
        assert pinned & set(before) <= set(c.pinned) | set(c.resident)
        check_cache(c)
    return c


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(0, 2 ** 31 - 1))
def test_cache_random_interleavings(seed):
    run_cache_program(seed)


def test_cache_validation():
    with pytest.raises(ValueError):
        ResidencyCache(0, [0, 1])
    with pytest.raises(ValueError):
        ResidencyCache(3, [0, 1])


def test_cache_all_pinned_refuses_stage():
    c = ResidencyCache(2, [0, 1, 2, 3])
    assert c.stage(0) == -1 and c.stage(1) == -1
    c.pin([0, 1])
    assert c.stage(2) is None          # no unpinned victim
    assert set(c.resident) == {0, 1}
    c.unpin()
    assert c.stage(2) == 0             # LRU unpinned victim


# ----------------------------------------------------------------------
# ExpertResidencyManager step-program fuzz
# ----------------------------------------------------------------------
def check_decision(mgr: ExpertResidencyManager, dec) -> None:
    topo = mgr.topo
    G, epr, W = topo.num_ranks, topo.experts_per_rank, mgr.W
    assert dec.residency_ids.shape == (G, W)
    for g in range(G):
        ids = [int(e) for e in dec.residency_ids[g] if e >= 0]
        assert len(ids) == len(set(ids)) <= W
        assert set(ids) <= {int(e) for e in topo.slot_map[g]}
        assert len(mgr.caches[g]) <= W
        assert not mgr.caches[g].pinned            # unpinned between steps
    rows = dec.stage_rows
    assert rows.tolist() == sorted(set(rows.tolist()))
    assert all(0 <= r < G * epr for r in rows.tolist())
    assert dec.hits >= 0 and dec.misses >= 0
    w = mgr.counters()
    assert w["hits"] + w["misses"] == w["lookups"]


def run_manager_program(seed: int, *, n_steps: int = 12) -> None:
    rng = random.Random(seed)
    G = rng.choice([1, 2, 4])
    E = G * rng.randint(1, 4)
    topo = make_topology(num_ranks=G, num_experts=E)
    assert isinstance(topo, EPTopology)
    epr = topo.experts_per_rank
    W = rng.randint(1, epr)
    policy = rng.choice(["predictive", "on_demand", "none"])
    mgr = ExpertResidencyManager(topo, W * G, policy=policy,
                                 cost=TierCostModel())
    load_rng = np.random.default_rng(seed)
    n_layers = rng.randint(1, 3)
    first_ids = mgr._last_ids.copy()
    for _ in range(n_steps):
        # sparse random per-layer loads (zeros = expert unused that layer)
        loads = load_rng.integers(0, 3, (n_layers, topo.padded_experts))
        dec = mgr.step(loads.astype(np.float64))
        check_decision(mgr, dec)
        if policy == "none":
            # frozen working set: no staging, table never changes
            assert dec.stage_rows.size == 0
            assert not dec.changed
            assert np.array_equal(dec.residency_ids, first_ids)
        if mgr.fully_resident:
            assert dec.misses == 0 and dec.stall_units == 0.0
    w = mgr.counters()
    if policy == "none":
        assert w["swaps"] == 0 and w["bytes_staged"] == 0.0
    if mgr.fully_resident:
        assert w["misses"] == 0 and (w["hit_rate"] in (None, 1.0))


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.integers(0, 2 ** 31 - 1))
def test_manager_random_streams(seed):
    run_manager_program(seed)


def test_manager_validation():
    topo = make_topology(num_ranks=2, num_experts=8)
    with pytest.raises(ValueError):
        ExpertResidencyManager(topo, 0)
    with pytest.raises(ValueError):
        ExpertResidencyManager(topo, 3)            # not a multiple of G
    with pytest.raises(ValueError):
        ExpertResidencyManager(topo, 10)           # W > experts_per_rank
    with pytest.raises(ValueError):
        ExpertResidencyManager(topo, 2, policy="psychic")


def test_predictive_prefetch_hides_the_stall():
    """Two MoE layers routing to disjoint expert pairs, W = 4 of 8: the
    predictive policy prefetches layer 1's pair during layer 0's compute
    window (bytes move, no stall), ``on_demand`` stalls once per expert
    on first touch, and ``none`` stalls on every single use — the
    module-level ordering the BENCH residency section measures end to
    end."""
    topo = make_topology(num_ranks=1, num_experts=8)
    slots = [int(e) for e in topo.slot_map[0]]
    loads = np.zeros((2, topo.padded_experts))
    loads[0, slots[0]] = loads[0, slots[1]] = 3.0   # layer 0: in the seed set
    loads[1, slots[4]] = loads[1, slots[5]] = 3.0   # layer 1: outside it
    stall = {}
    for policy in ("predictive", "on_demand", "none"):
        mgr = ExpertResidencyManager(topo, 4, policy=policy,
                                     cost=TierCostModel())
        stall[policy] = sum(mgr.step(loads).stall_units for _ in range(5))
        assert mgr.counters()["hits"] + mgr.counters()["misses"] == 20
    assert stall["predictive"] == 0.0        # both misses prefetched
    assert stall["on_demand"] == 2.0         # one stall per first touch
    assert stall["none"] == 10.0             # stalls every step, forever
