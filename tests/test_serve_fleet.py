"""Fleet router + engine-role tests on tiny CPU models.

Covers the ISSUE-mandated invariants: router scoring (load,
prefix-affinity, tie-breaking, round-robin, explicit assignment); a
1-replica fleet is bit-identical to the bare engine (tokens AND
timestamps — the shared-clock lockstep drive makes the reduction exact);
prefill→decode disaggregation is token-identical to a unified engine
(plain, prefix-sharing, and speculative-decode variants); fixed routing
assignments make token streams invariant across routing policies; no
replica recompiles after warmup; and the HandoffRecord wire form
round-trips bfloat16 KV exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import build_model
from repro.serve import (FleetRouter, HandoffRecord, Request, ServeEngine,
                         VirtualClock, engine_config_for, merge_requests,
                         poisson_requests, split_seeds)
from repro.serve.arrivals import AdmissionQueue

from _serve_helpers import captured_run

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _model(cfg, batch, seq_len):
    m = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                    batch=batch, seq_len=seq_len)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(cfg, model, params, clock, *, slots, prompt_len, max_new,
            chunk, **kw):
    ecfg = engine_config_for(cfg, max_slots=slots, prompt_len=prompt_len,
                             max_new_tokens=max_new, prefill_chunk=chunk,
                             **kw)
    return ServeEngine(model, params, ecfg, clock=clock)


def _captured_fleet_run(router, reqs):
    """Capture every replica's emitted token streams (hooked at
    ``_finish`` like tests/_serve_helpers.captured_run)."""
    outputs = {}
    for eng in router.engines:
        orig = eng._finish

        def capture(st, now, _orig=orig):
            outputs[st.req.rid] = list(st.output)
            _orig(st, now)

        eng._finish = capture
    rep = router.run(reqs)
    return outputs, rep


# ----------------------------------------------------------------------
# router units (stub engines: no devices, no jit)
# ----------------------------------------------------------------------
class _StubEngine:
    def __init__(self, clock, *, role="unified", load=0, prefix=0):
        self.role = role
        self.clock = clock
        self._load = load
        self._prefix = prefix
        self.queue = AdmissionQueue()
        self.submitted = []

    def load_stats(self):
        return {"queued_tokens": self._load, "kv_tokens": 0,
                "kv_utilization": 0.0, "active_slots": 0, "free_slots": 4,
                "pending_handoffs": 0}

    def probe_prefix(self, tokens):
        return self._prefix

    def submit(self, req):
        self.submitted.append(req)


def _req(rid, n=8):
    return Request(rid=rid, tokens=np.arange(n, dtype=np.int32) % 7,
                   max_new_tokens=4)


def test_route_load_picks_least_loaded():
    clock = VirtualClock()
    engines = [_StubEngine(clock, load=50), _StubEngine(clock, load=10),
               _StubEngine(clock, load=30)]
    fleet = FleetRouter(engines, policy="load")
    assert fleet._route(_req(0)) == 1


def test_route_ties_break_to_lowest_index():
    clock = VirtualClock()
    engines = [_StubEngine(clock, load=10), _StubEngine(clock, load=10)]
    fleet = FleetRouter(engines, policy="load")
    assert fleet._route(_req(0)) == 0
    # prefix_affinity with equal matches ties the same way
    fleet2 = FleetRouter([_StubEngine(clock, load=5, prefix=8),
                          _StubEngine(clock, load=5, prefix=8)],
                         policy="prefix_affinity")
    assert fleet2._route(_req(1)) == 0


def test_route_prefix_affinity_beats_load():
    """A big cached-prefix match outweighs a moderate load gap (and the
    hit is counted); with affinity_weight=0 the same fleet degenerates
    to pure load routing."""
    clock = VirtualClock()
    engines = [_StubEngine(clock, load=10, prefix=0),
               _StubEngine(clock, load=40, prefix=64)]
    fleet = FleetRouter(engines, policy="prefix_affinity",
                        affinity_weight=1.0)
    assert fleet._route(_req(0)) == 1
    assert fleet._affinity_hits == 1
    assert fleet._affinity_hit_tokens == 64
    flat = FleetRouter([_StubEngine(clock, load=10, prefix=0),
                        _StubEngine(clock, load=40, prefix=64)],
                       policy="prefix_affinity", affinity_weight=0.0)
    assert flat._route(_req(1)) == 0


def test_route_round_robin_cycles():
    clock = VirtualClock()
    engines = [_StubEngine(clock), _StubEngine(clock), _StubEngine(clock)]
    fleet = FleetRouter(engines, policy="round_robin")
    assert [fleet._route(_req(i)) for i in range(6)] == [0, 1, 2, 0, 1, 2]


def test_route_assignment_overrides_policy():
    clock = VirtualClock()
    engines = [_StubEngine(clock, load=0), _StubEngine(clock, load=999)]
    fleet = FleetRouter(engines, policy="load", assignment={7: 1})
    assert fleet._route(_req(7)) == 1
    assert fleet._decisions[-1]["policy"] == "assignment"


def test_router_validation():
    clock = VirtualClock()
    with pytest.raises(ValueError, match="at least one engine"):
        FleetRouter([])
    with pytest.raises(ValueError, match="routing policy"):
        FleetRouter([_StubEngine(clock)], policy="nope")
    with pytest.raises(ValueError, match="own clock"):
        FleetRouter([_StubEngine(clock), _StubEngine(VirtualClock())])
    with pytest.raises(ValueError, match="no unified/prefill"):
        FleetRouter([_StubEngine(clock, role="decode")])
    with pytest.raises(ValueError, match="no .*decode-role"):
        FleetRouter([_StubEngine(clock, role="prefill")])


def test_engine_role_config_validation():
    with pytest.raises(ValueError, match="unknown engine role"):
        engine_config_for(TINY, max_slots=1, prompt_len=8,
                          max_new_tokens=4, role="verify", paged=True)
    with pytest.raises(ValueError, match="require EngineConfig.paged"):
        engine_config_for(TINY, max_slots=1, prompt_len=8,
                          max_new_tokens=4, role="prefill")


# ----------------------------------------------------------------------
# arrivals: seeded sub-stream splitting
# ----------------------------------------------------------------------
def test_split_seeds_and_merge_requests():
    seeds = split_seeds(123, 3)
    assert len(set(seeds)) == 3
    assert seeds == split_seeds(123, 3)          # replayable
    streams = [poisson_requests(4, rate=2.0, vocab_size=64, prompt_len=8,
                                max_new_tokens=4, seed=s, rid_base=100 * i)
               for i, s in enumerate(seeds)]
    merged = merge_requests(*streams)
    assert len(merged) == 12
    times = [r.arrival_time for r in merged]
    assert times == sorted(times)
    with pytest.raises(ValueError, match="colliding rids"):
        merge_requests(streams[0], streams[0])


# ----------------------------------------------------------------------
# handoff wire form
# ----------------------------------------------------------------------
def test_handoff_record_npz_roundtrip_bfloat16():
    rng = np.random.default_rng(0)
    kv = [np.asarray(jnp.asarray(rng.standard_normal((8, 1, 2, 16)),
                                 jnp.bfloat16)),
          rng.standard_normal((8, 1, 2, 16)).astype(np.float32)]
    rec = HandoffRecord(
        rid=3, prompt_tokens=np.arange(6, dtype=np.int32), output=[11],
        pos=6, pad_len=8, prefill_chunk=4, max_new_tokens=5, eos_id=None,
        kv=kv, cached_prefix_tokens=0, arrival_time=0.25,
        admitted_time=0.5, first_token_time=1.0)
    back = HandoffRecord.from_npz_bytes(rec.to_npz_bytes())
    assert back.rid == 3 and back.pos == 6 and back.pad_len == 8
    assert back.eos_id is None and back.output == [11]
    assert back.first_token_time == 1.0
    np.testing.assert_array_equal(back.prompt_tokens, rec.prompt_tokens)
    for a, b in zip(kv, back.kv):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a.view(np.uint8), b.view(np.uint8))
    assert back.nbytes == rec.nbytes


# ----------------------------------------------------------------------
# 1-replica fleet == bare engine (tokens AND timestamps)
# ----------------------------------------------------------------------
def test_single_replica_fleet_matches_bare_engine():
    L, gen = 10, 5
    model, params = _model(TINY, 1, L)
    kw = dict(slots=2, prompt_len=L, max_new=gen, chunk=4, paged=True,
              kv_block_size=4)
    reqs = lambda: poisson_requests(5, rate=2.0, vocab_size=TINY.vocab_size,
                                    prompt_len=L, max_new_tokens=gen,
                                    seed=7)

    bare = _engine(TINY, model, params, VirtualClock(0.5), **kw)
    want, bare_rep = captured_run(bare, reqs())

    eng = _engine(TINY, model, params, VirtualClock(0.5), **kw)
    fleet = FleetRouter([eng], policy="load")
    got, fleet_rep = _captured_fleet_run(fleet, reqs())

    assert got == want
    # the lockstep drive keeps the shared clock call-for-call identical,
    # so per-request timestamps (not just tokens) match exactly
    rows = {r["rid"]: r for r in fleet_rep["replica_reports"][0]["requests"]}
    for r in bare_rep["requests"]:
        assert rows[r["rid"]]["ttft"] == r["ttft"]
        assert rows[r["rid"]]["e2e"] == r["e2e"]
    agg = fleet_rep["fleet"]["aggregate"]
    assert agg["n_requests"] == bare_rep["n_requests"]
    assert agg["ttft"]["p50"] == bare_rep["ttft"]["p50"]


# ----------------------------------------------------------------------
# prefill→decode disaggregation == unified engine (token identity)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("variant", ["plain", "prefix", "spec"])
def test_disaggregated_matches_unified_tokens(variant):
    L, gen = 10, 6
    model, params = _model(TINY, 1, L)
    base = dict(slots=2, prompt_len=L, max_new=gen, chunk=4, paged=True,
                kv_block_size=4)
    sharing = variant == "prefix"
    spec_k = 4 if variant == "spec" else 0
    shared_prefix = 8 if sharing else 0
    reqs = lambda: poisson_requests(6, rate=2.0,
                                    vocab_size=TINY.vocab_size,
                                    prompt_len=L, max_new_tokens=gen,
                                    seed=11,
                                    shared_prefix_len=shared_prefix)

    uni = _engine(TINY, model, params, VirtualClock(0.5), **base,
                  prefix_sharing=sharing)
    want, _ = captured_run(uni, reqs())

    clock = VirtualClock(0.5)
    pf = _engine(TINY, model, params, clock, **base, role="prefill",
                 prefix_sharing=sharing)
    dec = _engine(TINY, model, params, clock, **base, role="decode",
                  prefix_sharing=sharing, speculative_k=spec_k)
    fleet = FleetRouter([pf, dec], policy="load")
    assert fleet.disaggregated
    got, rep = _captured_fleet_run(fleet, reqs())

    assert got == want
    hand = rep["fleet"]["handoffs"]
    assert hand["moved"] == 6 and hand["pending"] == 0
    assert hand["bytes"] > 0
    roles = {r["role"]: r for r in rep["fleet"]["replicas"]}
    assert roles["prefill"]["handoffs"]["exported"] == 6
    assert roles["decode"]["handoffs"]["imported"] == 6
    # every completion record lives on the decode side, with the true
    # (prefill-stamped) TTFT carried across the handoff
    assert roles["decode"]["n_requests"] == 6
    assert roles["prefill"]["n_requests"] == 0


def test_decode_role_rejects_submit():
    model, params = _model(TINY, 1, 8)
    dec = _engine(TINY, model, params, VirtualClock(0.5), slots=1,
                  prompt_len=8, max_new=4, chunk=4, paged=True,
                  kv_block_size=4, role="decode")
    with pytest.raises(ValueError, match="import_handoff"):
        dec.submit(_req(0))


# ----------------------------------------------------------------------
# routing only places work: fixed assignment => identical streams
# ----------------------------------------------------------------------
def test_fixed_assignment_identical_across_policies():
    L, gen = 10, 5
    model, params = _model(TINY, 1, L)
    kw = dict(slots=2, prompt_len=L, max_new=gen, chunk=4, paged=True,
              kv_block_size=4, prefix_sharing=True)
    reqs = lambda: poisson_requests(6, rate=2.0,
                                    vocab_size=TINY.vocab_size,
                                    prompt_len=L, max_new_tokens=gen,
                                    seed=5, shared_prefix_len=8)

    def run(policy, assignment=None):
        clock = VirtualClock(0.5)
        engines = [_engine(TINY, model, params, clock, **kw)
                   for _ in range(2)]
        fleet = FleetRouter(engines, policy=policy, assignment=assignment)
        outs, rep = _captured_fleet_run(fleet, reqs())
        decisions = {d["rid"]: d["replica"]
                     for d in rep["fleet"]["routing"]["decisions"]}
        return outs, decisions

    out_load, placed = run("load")
    # replay the load policy's placement under every other policy: the
    # assignment overrides scoring, so the streams must be bit-identical
    for policy in ("prefix_affinity", "round_robin"):
        out_replay, placed_replay = run(policy, assignment=placed)
        assert placed_replay == placed
        assert out_replay == out_load


# ----------------------------------------------------------------------
# fleet warmup: zero post-warmup recompiles on every replica
# ----------------------------------------------------------------------
def test_fleet_zero_recompiles_after_warmup():
    L, gen = 10, 5
    model, params = _model(TINY, 1, L)
    clock = VirtualClock(0.5)
    engines = [_engine(TINY, model, params, clock, slots=2, prompt_len=L,
                       max_new=gen, chunk=4, paged=True, kv_block_size=4,
                       prefix_sharing=True) for _ in range(2)]
    fleet = FleetRouter(engines, policy="prefix_affinity")
    fleet.warmup()
    rep = fleet.run(poisson_requests(6, rate=2.0,
                                     vocab_size=TINY.vocab_size,
                                     prompt_len=L, max_new_tokens=gen,
                                     seed=3, shared_prefix_len=8))
    for rrep in rep["replica_reports"]:
        assert rrep["recompiled_after_warmup"] is False
    routing = rep["fleet"]["routing"]
    assert sum(routing["per_replica"]) == 6
    assert rep["fleet"]["aggregate"]["n_requests"] == 6
