"""EngineConfig.validate(): every illegal combination raises coherently.

The validation is consolidated in one method (``EngineConfig.validate``,
run from ``__post_init__``), so an ``EngineConfig`` that exists is valid
and each illegal field combination fails at construction with an error
that names the offending knob.  Model-*dependent* checks (MoE knobs on
dense models, SSM paging, ring restrictions) are covered where the model
is in hand — ``test_serve_ssm.py`` / ``test_serve_window_ring.py``.
"""
import dataclasses

import pytest

from repro.serve import EngineConfig


def make(**kw):
    return EngineConfig(**kw)


# (kwargs, error fragment) — one row per illegal combination validate()
# rejects.  The fragment must appear in the message so errors stay
# attributable to the knob that caused them.
ILLEGAL = [
    # shapes
    (dict(max_slots=0), "max_slots"),
    (dict(max_seq_len=0), "max_seq_len"),
    (dict(max_slots=-3), "max_slots"),
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(chunks_per_step=0), "chunks_per_step"),
    # roles
    (dict(role="verifier"), "unknown engine role"),
    (dict(role="prefill"), "paged"),
    (dict(role="decode"), "paged"),
    # paged pool
    (dict(paged=True, kv_block_size=0), "kv_block_size"),
    (dict(num_kv_blocks=-1), "num_kv_blocks"),
    (dict(prefix_sharing=True), "paged"),
    (dict(fused_paged_attention=True), "paged"),
    # speculative
    (dict(speculative_k=-1), "speculative_k"),
    (dict(speculative_k=2), "paged"),
    # sampling
    (dict(temperature=-0.5), "temperature"),
    (dict(top_k=-1), "top_k"),
    (dict(top_p=0.0), "top_p"),
    (dict(top_p=1.5), "top_p"),
    # MoE serving knobs
    (dict(moe_policy="greedy"), "moe_policy"),
    (dict(replica_slots=-1), "replica_slots"),
    (dict(rebalance_interval=-1), "rebalance_interval"),
    (dict(rebalance_interval=4), "replica_slots"),
    # residency
    (dict(resident_experts=-1), "resident_experts"),
    (dict(prefetch_policy="psychic"), "prefetch_policy"),
]


@pytest.mark.parametrize("kw,frag", ILLEGAL,
                         ids=["_".join(f"{k}={v}" for k, v in kw.items())
                              for kw, _ in ILLEGAL])
def test_illegal_combinations_raise(kw, frag):
    with pytest.raises(ValueError, match=frag):
        make(**kw)


def test_defaults_are_valid():
    cfg = EngineConfig()
    assert cfg.validate() is cfg        # chaining returns self


def test_legal_combinations_construct():
    # the features each gated knob unlocks, with their gates satisfied
    make(paged=True, prefix_sharing=True, speculative_k=3,
         fused_paged_attention=True, role="prefill")
    make(role="decode", paged=True)
    make(temperature=0.7, top_k=5, top_p=0.9)
    make(replica_slots=2, rebalance_interval=8)
    make(moe_policy="harmoeny", resident_experts=4,
         prefetch_policy="on_demand")


def test_replace_reruns_validation():
    """``dataclasses.replace`` re-runs ``__post_init__``, so a valid
    config cannot be mutated into an illegal combination silently —
    dropping a gate (paged) out from under its dependents raises too."""
    with pytest.raises(ValueError, match="top_p"):
        dataclasses.replace(EngineConfig(), top_p=2.0)
    cfg = EngineConfig(paged=True, speculative_k=2)
    with pytest.raises(ValueError, match="paged"):
        dataclasses.replace(cfg, paged=False)
