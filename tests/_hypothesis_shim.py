"""Minimal stand-in for the slice of the `hypothesis` API this suite uses.

When the real `hypothesis` package is unavailable, property tests fall back
to deterministic seeded sampling: ``@given`` re-runs the test body for
``max_examples`` draws from the strategies (``integers``, ``sampled_from``,
``booleans``). No shrinking, no example database — just enough randomized
coverage that the tier-1 suite runs green without optional deps.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

_SEED = 0x5EED


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: opts[rng.randrange(len(opts))])


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


strategies = types.SimpleNamespace(
    integers=_integers, sampled_from=_sampled_from, booleans=_booleans)


def settings(max_examples: int = 10, deadline=None, **_kw):
    """Records max_examples on the (possibly already @given-wrapped) test."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 10))
            rng = random.Random(_SEED)
            for i in range(n):
                drawn = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={drawn}") from e
        # hide the strategy-fed params from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
