"""Chunked CE vs full softmax; AdamW vs numpy reference; int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.losses import chunked_softmax_xent, logits_head
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compress import dequantize_int8, quantize_int8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([5, 16, 33]),
       st.sampled_from([4, 8, 64]))
def test_chunked_xent_matches_full(seed, S, chunk):
    B, d, V, Vp = 2, 8, 50, 64
    key = jax.random.PRNGKey(seed)
    h = jax.random.normal(jax.random.fold_in(key, 0), (B, S, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (Vp, d)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    got = chunked_softmax_xent(h, w, labels, real_vocab=V, chunk=chunk)
    logits = h @ w.T
    logits = jnp.where(jnp.arange(Vp)[None, None] < V, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    want = (lse - lab).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_xent_softcap_and_ignore():
    B, S, d, V, Vp = 1, 8, 4, 10, 16
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (Vp, d))
    labels = jnp.array([[1, 2, 3, -1, -1, 4, 5, 6]])
    loss = chunked_softmax_xent(h, w, labels, real_vocab=V, chunk=4,
                                softcap=30.0)
    assert np.isfinite(float(loss))


def test_logits_head_masks_padded_vocab():
    logits = logits_head(jnp.ones((2, 4)), jnp.ones((8, 4)), real_vocab=5)
    assert (np.asarray(logits)[:, 5:] < -1e20).all()


def test_adamw_matches_numpy_reference():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.1])}
    state = adamw_init(params)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    p_np = np.array([1.0, -2.0, 3.0])
    m = np.zeros(3)
    v = np.zeros(3)
    p, s = params, state
    for t in range(1, 4):
        p, s = adamw_update(grads, s, p, lr=lr, b1=b1, b2=b2, eps=eps,
                            weight_decay=wd, grad_clip=1e9)
        g = np.array([0.1, 0.2, -0.1])
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        p_np = p_np - lr * (mh / (np.sqrt(vh) + eps) + wd * p_np)
        np.testing.assert_allclose(np.asarray(p["w"]), p_np, rtol=1e-5)


def test_adamw_grad_clip():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}  # norm 200 -> clipped to 1
    state = adamw_init(params)
    p1, _ = adamw_update(grads, state, params, lr=1.0, weight_decay=0.0,
                         grad_clip=1.0)
    # after clipping, effective g = 0.5 per coord; first step delta ~= lr
    assert np.abs(np.asarray(p1["w"]) - 1.0).max() <= 1.01


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_quantization_bounded_error(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * 10
    q, scale = quantize_int8(x, jax.random.fold_in(key, 1))
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 1.01  # within one quantization step
