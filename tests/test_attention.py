"""Attention correctness: chunked-flash vs naive, SWA, softcap, GQA, decode
against ring and linear caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.models.attention import (AttnCache, attention_block,
                                    chunked_attention, decode_attention,
                                    full_attention_ref, init_attention)


@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, True), (0, 0.0, False), (16, 0.0, True), (0, 30.0, True),
    (8, 50.0, True),
])
def test_chunked_matches_naive(window, softcap, causal):
    B, Sq, H, Hkv, hd = 2, 48, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, Hkv, hd))
    a = chunked_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, chunk=16)
    b = full_attention_ref(q, k, v, causal=causal, window=window,
                           softcap=softcap)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 24, 33]),
       st.sampled_from([4, 16, 32]))
def test_chunked_chunk_size_independent(seed, S, chunk):
    B, H, hd = 1, 2, 8
    key = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd))
               for i in range(3))
    a = chunked_attention(q, k, v, causal=True, chunk=chunk)
    b = full_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_decode_matches_full():
    """Decode at position t == row t of full causal attention."""
    B, S, H, hd = 1, 10, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    full = full_attention_ref(q, k, v, causal=True)
    for t in [0, 4, 9]:
        out = decode_attention(q[:, t:t + 1], k, v, jnp.int32(t + 1))
        np.testing.assert_allclose(np.asarray(out)[:, 0],
                                   np.asarray(full)[:, t], atol=2e-5)


def _swa_cfg(window):
    return ModelConfig(name="tiny-swa", family="dense", num_layers=1,
                       d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
                       vocab_size=32, head_dim=8, dtype="float32",
                       sliding_window=window)


@pytest.mark.parametrize("S", [10, 13, 16])
def test_prefill_ring_rotation(S):
    """Prefill of S > S_max tokens into a window-sized ring cache, then
    decode: must match a full-length cache with an explicit window mask.

    Regression: the ring tail used to be stored at indices [0, S_max), but
    decode writes land at (cache_len - 1) % S_max — whenever S % S_max != 0
    the ring was rotated relative to the write cursor and decode evicted a
    mid-window token instead of the oldest one."""
    W = 8
    cfg = _swa_cfg(W)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, n_dec = 1, 2 * W
    xs = jax.random.normal(jax.random.PRNGKey(1),
                           (B, S + n_dec, cfg.d_model)) * 0.3

    def run(s_max):
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = AttnCache(jnp.zeros((B, s_max, hkv, hd)),
                          jnp.zeros((B, s_max, hkv, hd)))
        outs = []
        y, cache = attention_block(xs[:, :S], p, cfg, cache=cache,
                                   cache_len=jnp.int32(S))
        outs.append(y[:, -1])
        for t in range(S, S + n_dec):
            y, cache = attention_block(xs[:, t:t + 1], p, cfg,
                                       q_offset=jnp.int32(t), cache=cache,
                                       cache_len=jnp.int32(t + 1))
            outs.append(y[:, 0])
        return np.asarray(jnp.stack(outs, axis=1))

    ring, full = run(W), run(S + n_dec)
    np.testing.assert_allclose(ring, full, atol=3e-5)


def test_paged_decode_rejects_binding_window():
    """The paged decode branch attends window-free; a sliding window that
    could actually mask something (window < logical range) must raise
    instead of being silently dropped."""
    cfg = _swa_cfg(8)
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, bs, n_logical = 2, 4, 4                 # L_max = 16 > window = 8
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    P = (1 + B * n_logical) * bs
    pool = AttnCache(jnp.zeros((1, P, hkv, hd)), jnp.zeros((1, P, hkv, hd)))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    bt = jnp.ones((B, n_logical), jnp.int32)
    with pytest.raises(NotImplementedError, match="sliding window"):
        attention_block(x, p, cfg, q_offset=jnp.zeros((B,), jnp.int32),
                        cache=pool, cache_len=jnp.ones((B,), jnp.int32),
                        block_table=bt, block_size=bs)
    # a window that can never bind (window >= L_max) is dropped exactly
    cfg_wide = _swa_cfg(bs * n_logical)
    y, _ = attention_block(x, p, cfg_wide,
                           q_offset=jnp.zeros((B,), jnp.int32),
                           cache=pool, cache_len=jnp.ones((B,), jnp.int32),
                           block_table=bt, block_size=bs)
    assert y.shape == (B, 1, cfg.d_model)


def test_decode_ring_buffer_equivalence():
    """A window-sized ring cache gives the same result as masking a full
    cache to the window (mixtral long_500k mechanism)."""
    B, H, hd, W = 1, 2, 8, 8
    total = 20
    ks = jax.random.normal(jax.random.PRNGKey(0), (B, total, H, hd))
    vs = jax.random.normal(jax.random.PRNGKey(1), (B, total, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    t = 15  # cache_len
    # full cache + window mask
    ref = decode_attention(q, ks[:, :t], vs[:, :t], jnp.int32(t), window=W)
    # ring cache holding the last W entries at wrapped positions
    ring_k = jnp.zeros((B, W, H, hd))
    ring_v = jnp.zeros((B, W, H, hd))
    for pos in range(t - W, t):
        ring_k = ring_k.at[:, pos % W].set(ks[:, pos])
        ring_v = ring_v.at[:, pos % W].set(vs[:, pos])
    out = decode_attention(q, ring_k, ring_v, jnp.int32(W))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
