"""Attention correctness: chunked-flash vs naive, SWA, softcap, GQA, decode
against ring and linear caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.attention import (AttnCache, chunked_attention,
                                    decode_attention, full_attention_ref)


@pytest.mark.parametrize("window,softcap,causal", [
    (0, 0.0, True), (0, 0.0, False), (16, 0.0, True), (0, 30.0, True),
    (8, 50.0, True),
])
def test_chunked_matches_naive(window, softcap, causal):
    B, Sq, H, Hkv, hd = 2, 48, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, Hkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, Hkv, hd))
    a = chunked_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, chunk=16)
    b = full_attention_ref(q, k, v, causal=causal, window=window,
                           softcap=softcap)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 24, 33]),
       st.sampled_from([4, 16, 32]))
def test_chunked_chunk_size_independent(seed, S, chunk):
    B, H, hd = 1, 2, 8
    key = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd))
               for i in range(3))
    a = chunked_attention(q, k, v, causal=True, chunk=chunk)
    b = full_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_decode_matches_full():
    """Decode at position t == row t of full causal attention."""
    B, S, H, hd = 1, 10, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    full = full_attention_ref(q, k, v, causal=True)
    for t in [0, 4, 9]:
        out = decode_attention(q[:, t:t + 1], k, v, jnp.int32(t + 1))
        np.testing.assert_allclose(np.asarray(out)[:, 0],
                                   np.asarray(full)[:, t], atol=2e-5)


def test_decode_ring_buffer_equivalence():
    """A window-sized ring cache gives the same result as masking a full
    cache to the window (mixtral long_500k mechanism)."""
    B, H, hd, W = 1, 2, 8, 8
    total = 20
    ks = jax.random.normal(jax.random.PRNGKey(0), (B, total, H, hd))
    vs = jax.random.normal(jax.random.PRNGKey(1), (B, total, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    t = 15  # cache_len
    # full cache + window mask
    ref = decode_attention(q, ks[:, :t], vs[:, :t], jnp.int32(t), window=W)
    # ring cache holding the last W entries at wrapped positions
    ring_k = jnp.zeros((B, W, H, hd))
    ring_v = jnp.zeros((B, W, H, hd))
    for pos in range(t - W, t):
        ring_k = ring_k.at[:, pos % W].set(ks[:, pos])
        ring_v = ring_v.at[:, pos % W].set(vs[:, pos])
    out = decode_attention(q, ring_k, ring_v, jnp.int32(W))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
