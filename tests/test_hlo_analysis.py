"""Trip-count-aware HLO analyzer: the roofline's foundation."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_mesh
from repro.core.compat import shard_map


def test_scan_flops_multiplied_by_trip_count():
    d, n = 128, 8
    w = jnp.zeros((n, d, d))
    x = jnp.zeros((4, d))
    co = jax.jit(
        lambda x: jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    ).lower(x).compile()
    r = analyze(co.as_text())
    assert r["flops"] == pytest.approx(n * 2 * 4 * d * d)
    # sanity: XLA's own analysis counts the body once (the reason this
    # module exists)
    ca = co.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca  # list-of-dicts pre-0.5 jax
    assert ca["flops"] < r["flops"] / (n - 1)


def test_collectives_inside_scan_counted_per_iteration():
    mesh = make_mesh((1,), ("m",))
    P = jax.sharding.PartitionSpec
    w = jnp.zeros((8, 64, 64))
    x = jnp.zeros((4, 64))

    def f(x):
        def step(c, wi):
            return jax.lax.psum(c @ wi, "m"), None
        return jax.lax.scan(step, x, w)[0]

    g = shard_map(f, mesh=mesh, in_specs=P(None, None),
                      out_specs=P(None, None), check_vma=False)
    r = analyze(jax.jit(g).lower(x).compile().as_text())
    assert r["collective_counts"]["all-reduce"] == 8


def test_nested_scan_multiplies():
    x = jnp.zeros((4, 64))
    w = jnp.zeros((3, 5, 64, 64))

    def inner(c, wi):
        return jax.lax.scan(lambda cc, wj: (cc @ wj, None), c, wi)[0]
    co = jax.jit(
        lambda x: jax.lax.scan(lambda c, wi: (inner(c, wi), None), x, w)[0]
    ).lower(x).compile()
    r = analyze(co.as_text())
    assert r["flops"] == pytest.approx(3 * 5 * 2 * 4 * 64 * 64)
