"""Checkpointing: roundtrip, torn-write safety, CRC, keep-k, elastic reshard."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_mesh


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    ck.save(10, t, blocking=True)
    assert ck.latest_step() == 10
    out = ck.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    ck.wait()
    assert ck.latest_step() == 1


def test_torn_write_invisible(tmp_path):
    """A checkpoint dir without its .done marker is ignored."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=True)
    os.makedirs(tmp_path / "step_9")
    with open(tmp_path / "step_9" / "manifest.json", "w") as f:
        json.dump({"step": 9, "leaves": []}, f)
    assert ck.latest_step() == 5


def test_crc_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(), blocking=True)
    leaf = tmp_path / "step_3" / "leaf_0.npy"
    arr = np.load(leaf)
    arr.flat[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="corrupt"):
        ck.restore(3, _tree())


def test_keep_k_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    steps = sorted(int(n[5:-5]) for n in os.listdir(tmp_path)
                   if n.endswith(".done"))
    assert steps == [3, 4]


def test_elastic_reshard_on_restore(tmp_path):
    """A checkpoint written replicated restores onto a different sharding —
    the mesh-change (elastic restart) path."""
    mesh = make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t, blocking=True)
    sh = jax.tree.map(
        lambda l: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*([None] * l.ndim))), t)
    out = ck.restore(7, t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert isinstance(b, jax.Array)
