"""Dispatch/combine layout properties on a single rank (G=1 degenerates the
all_to_all to identity, isolating the index bookkeeping)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import dispatch as D
from repro.core.scheduler import initial_assign
from repro.core.topology import make_topology


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([1, 2]))
def test_layout_roundtrip_identity_experts(seed, E, k):
    """dispatch -> identity expert -> combine reproduces gate-weighted input."""
    G, T, d, bm = 1, 24, 8, 4
    topo = make_topology(G, E)
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(rng.integers(0, E, (T, k)).astype(np.int32))
    gates = jnp.asarray(rng.random((T, k)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((T, d)).astype(np.float32))
    counts = jnp.zeros((1, E), jnp.int32).at[0, assign.reshape(-1)].add(1)
    S = initial_assign(counts, topo)
    c_pair = 8
    c_total = T * k + (E + 2) * bm
    me = jnp.int32(0)
    layout = D.build_layout(S, assign, me, topo, c_pair=c_pair,
                            c_total=c_total, num_foreign_slots=2, block_m=bm)
    x_units = jnp.repeat(x, k, axis=0)

    # single-rank: emulate dispatch without the all_to_all
    grouped = jnp.zeros((c_total, d)).at[layout.unit_row_self].set(
        x_units, mode="drop")
    y = D.combine(grouped, layout, axis_name=None, num_ranks=G,
                  c_pair=c_pair, gates=gates, top_k=k) \
        if False else None
    # combine uses all_to_all; emulate its self path directly instead:
    pad = jnp.concatenate([grouped, jnp.zeros((1, d))], axis=0)
    y_units = pad[jnp.minimum(layout.unit_row_self, c_total)]
    y = (y_units.reshape(T, k, d) * gates[..., None]).sum(axis=1)

    want = (jnp.repeat(x, k, 0).reshape(T, k, d) * gates[..., None]).sum(1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)
    # every unit landed in a distinct row
    rows = np.asarray(layout.unit_row_self)
    assert len(set(rows.tolist())) == T * k
    # group sizes match histograms
    sizes = np.asarray(layout.group_sizes)[:topo.experts_per_rank]
    hist = np.bincount(np.asarray(assign).reshape(-1), minlength=E)
    slot_experts = topo.slot_map[0]
    for j, e in enumerate(slot_experts):
        assert sizes[j] == hist[e]


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_group_offsets_block_aligned(seed):
    G, E, T, k, bm = 1, 8, 40, 2, 8
    topo = make_topology(G, E)
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(rng.integers(0, E, (T, k)).astype(np.int32))
    counts = jnp.zeros((1, E), jnp.int32).at[0, assign.reshape(-1)].add(1)
    S = initial_assign(counts, topo)
    layout = D.build_layout(S, assign, jnp.int32(0), topo, c_pair=8,
                            c_total=T * k + (E + 2) * bm,
                            num_foreign_slots=2, block_m=bm)
    offs = np.asarray(layout.group_offsets)
    assert (offs % bm == 0).all()
    assert (np.diff(offs) >= 0).all()


def test_padding_sentinel_units_dropped():
    """Units marked with the sentinel expert id Ep are never scheduled."""
    G, E, k, bm = 1, 4, 1, 4
    topo = make_topology(G, E)
    assign = jnp.array([[0], [1], [E], [E]], jnp.int32)  # 2 padding units
    counts = jnp.zeros((1, E), jnp.int32).at[0, assign[:2, 0]].add(1)
    S = initial_assign(counts, topo)
    layout = D.build_layout(S, assign, jnp.int32(0), topo, c_pair=8,
                            c_total=64, num_foreign_slots=1, block_m=bm)
    rows = np.asarray(layout.unit_row_self)
    assert (rows[2:] == 64).all()          # dropped (out of range)
    assert (rows[:2] < 64).all()
    assert int(layout.group_sizes.sum()) == 2
