"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.moe_gmm.ops import fused_expert_ffn, tile_group_map
from repro.kernels.moe_gmm.ref import moe_gmm_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("gated", [True, False])
@pytest.mark.parametrize("sizes", [
    [16, 0, 24, 8],          # empty group in the middle
    [0, 0, 0, 48],           # all load on the last expert (heavy skew)
    [8, 8, 8, 8],            # uniform
    [48, 0, 0, 0],           # all load on the first expert
])
def test_moe_gmm_sweep(dtype, gated, sizes):
    bm, d, f, G = 8, 32, 64, 4
    M = 64
    key = jax.random.PRNGKey(0)
    sizes = jnp.array(sizes, jnp.int32)
    x = (jax.random.normal(key, (M, d)) * 0.5).astype(dtype)
    # zero rows beyond group content (dispatch buffer invariant)
    row_group = jnp.repeat(tile_group_map(sizes, M // bm, bm), bm)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sizes)[:-1]])
    w_in = (jax.random.normal(jax.random.PRNGKey(1), (G, d, f)) * 0.1).astype(dtype)
    w_gate = (jax.random.normal(jax.random.PRNGKey(2), (G, d, f)) * 0.1).astype(dtype)
    w_out = (jax.random.normal(jax.random.PRNGKey(3), (G, f, d)) * 0.1).astype(dtype)
    kw = dict(w_gate=w_gate, act="silu") if gated else dict(act="gelu")
    out_k = fused_expert_ffn(x, w_in, w_out, sizes, block_m=bm, block_f=32,
                             interpret=True, **kw)
    tg = tile_group_map(sizes, M // bm, bm)
    out_r = moe_gmm_ref(x, w_in, w_out, tg, block_m=bm, **kw)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_moe_gmm_matches_grouped_ffn_ref():
    """The kernel and the XLA tile-scan reference (core/grouped_ffn) agree."""
    from repro.core.grouped_ffn import grouped_ffn_ref
    bm, d, f, G, M = 8, 16, 32, 3, 48
    sizes = jnp.array([16, 8, 24], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(0), (M, d))
    w_in = jax.random.normal(jax.random.PRNGKey(1), (G, d, f)) * 0.1
    w_out = jax.random.normal(jax.random.PRNGKey(2), (G, f, d)) * 0.1
    a = fused_expert_ffn(x, w_in, w_out, sizes, act="gelu", block_m=bm,
                         block_f=16, interpret=True)
    b = grouped_ffn_ref(x, w_in, w_out, sizes, act="gelu", block_m=bm)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (1, 4, 4, 64, 16),    # MHA
    (2, 4, 2, 128, 16),   # GQA rep=2
    (1, 8, 1, 64, 32),    # MQA
])
def test_flash_attention_sweep(dtype, causal, shape):
    B, H, Hkv, S, hd = shape
    q = (jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd))).astype(dtype)
    k = (jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, hd))).astype(dtype)
    v = (jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, hd))).astype(dtype)
    o_k = flash_attention_kernel(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_block_shape_independence():
    B, H, S, hd = 1, 2, 128, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd))
    outs = [flash_attention_kernel(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
            for bq, bk in [(32, 32), (64, 32), (128, 64), (32, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)
