"""Regression: chunked ``fetch_foreign_weights`` parity (multi-device
subprocess, like test_distributed.py).

The chunked path zero-pads the last dimension up to a multiple of
``fetch_chunk`` before the per-chunk einsum/all_to_all, and every source
contribution flows through ``mask / hosts_per_expert``. The hazard under
test: a padded tail that survives the mean-over-hosts reduction, or a
chunk-reassembly permutation, would silently corrupt the trailing columns
of fetched foreign experts — exactly the columns an odd ``d_ff`` leaves
past the last full chunk. So every cell uses an odd last dimension with a
non-dividing ``fetch_chunk`` and checks, elementwise:

* chunked output == unchunked (``fetch_chunk=0``) output, bit-exact in
  float32 (identical math, reordered only along sliced-off padding);
* both match a numpy oracle: mean over the expert's host rows — which is
  only non-trivial when ``hosts_per_expert > 1`` (E < G replication);
* ``-1`` foreign ids (unused slots) fetch exact zeros through both paths;
* a ``fetch_chunk`` larger than the last dimension degrades to the
  unchunked path (the guard, not a 1-chunk pad cycle).
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_fetch_chunked_padding_parity():
    out = _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.compat import shard_map
    from repro.core.prefetch import fetch_foreign_weights
    from repro.core.topology import make_topology

    def cell(G, E, d, F, K, chunks, dtype=jnp.float32, with_empty=False):
        mesh = Mesh(np.array(jax.devices()[:G]), ("model",))
        topo = make_topology(G, E)
        epr = topo.experts_per_rank
        rng = np.random.default_rng(G * 100 + E)
        w = rng.normal(size=(G * epr, d, F)).astype(dtype)

        # K foreign experts per rank (never locally hosted); optionally
        # leave the last slot unused (-1) to cover the no-fetch path.
        fids = np.zeros((G, K), np.int32)
        for g in range(G):
            local = {int(e) for e in topo.slot_map[g]}
            cand = [e for e in range(E) if e not in local]
            fids[g] = (cand * K)[:K]
        if with_empty:
            fids[:, -1] = -1

        def run(chunk):
            def body(w_local):
                me = jax.lax.axis_index("model")
                return fetch_foreign_weights(
                    w_local, jnp.asarray(fids), me, topo,
                    axis_name="model", fetch_chunk=chunk)
            f = shard_map(body, mesh=mesh, in_specs=P("model"),
                          out_specs=P("model"))
            with mesh:
                return np.asarray(jax.jit(f)(jnp.asarray(w)))

        # numpy oracle: dst g's k-th fetch = mean over the host rows
        ref = np.zeros((G * K, d, F), np.float64)
        w64 = w.astype(np.float64)
        for g in range(G):
            for k in range(K):
                e = int(fids[g, k])
                if e < 0:
                    continue                      # unused slot: zeros
                rows = [h * epr + int(np.argmax(topo.slot_map[h] == e))
                        for h in topo.host_of[e]]
                ref[g * K + k] = (sum(w64[r] for r in rows)
                                  / topo.hosts_per_expert)

        base = run(0)
        tol = 0.0 if dtype == jnp.float32 else 5e-2
        assert np.allclose(base.astype(np.float64), ref, atol=tol), \\
            f"unchunked vs oracle G={G} E={E}"
        for c in chunks:
            got = run(c)
            assert np.array_equal(got, base), \\
                f"chunk={c} diverged G={G} E={E} F={F}"
        print(f"cell G={G} E={E} F={F} hpe={topo.hosts_per_expert} ok")

    # hosts_per_expert > 1 (E < G): padded tail crosses the host mean
    cell(8, 4, 3, 7, 2, [3, 5], with_empty=True)   # hpe=2, odd F
    cell(8, 2, 2, 5, 1, [2, 3])                    # hpe=4
    # E > G (epr > 1): the common big-model shape, odd F again
    cell(4, 8, 3, 7, 2, [3, 4], with_empty=True)
    # fetch_chunk >= F takes the unchunked early-out, still exact
    cell(4, 2, 2, 7, 1, [7, 16])                   # hpe=2
    # low precision: pad/chunk reassembly must stay bit-identical even
    # when the 1/hosts_per_expert scale itself rounds
    cell(4, 2, 2, 7, 1, [4], dtype=jnp.bfloat16)
    print("OK")
    """)
    assert "OK" in out
