"""Paged sliding-window ring buffers: differential vs the windowed oracle.

A window-clamped attention layer used to be a loud rejection in the paged
engine (`padded prompt exceeds the sliding window`).  It is now served as
a fixed-size ring: each slot owns a whole chain of
``round_up(window, block_size)`` tokens, logical position p lives at ring
slot ``p % M``, and decode gathers through
``paged_ring_decode_attention``.  Invariants:

* greedy streams are token-identical to the one-shot windowed oracle
  (clamped-slab prefill + decode) for prompts shorter than, equal to,
  and far beyond the window — including non-block-multiple and
  non-chunk-multiple lengths, whose partial final chunks make pad
  positions wrap the ring (the null-block diversion keeps them from
  clobbering in-window K/V);
* preemption + resume through the ring is token-exact;
* ring chains are allocated whole at admission and never grow;
* the features whose semantics a ring breaks (speculative verify, prefix
  sharing, fused paged attention, split roles, chunk > ring) are rejected
  at engine construction with errors naming the blocker.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import build_model
from repro.serve import Request, ServeEngine, VirtualClock, engine_config_for

from _serve_helpers import captured_run

SWA = ModelConfig(name="tinyswa", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=16, sliding_window=8, dtype="float32")
L_MAX, GEN, CHUNK, BS = 14, 6, 4, 4


@pytest.fixture(scope="module")
def swa():
    model = build_model(SWA, ParallelConfig(attn_chunk=8, loss_chunk=8),
                        batch=1, seq_len=L_MAX)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    ecfg = engine_config_for(SWA, max_slots=2, prompt_len=L_MAX,
                             max_new_tokens=GEN, prefill_chunk=CHUNK,
                             paged=True, kv_block_size=BS, **kw)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.5))


def _oracle(model, params, prompt, s_max, gen=GEN):
    """One-shot prefill + lockstep decode on the window-clamped slab."""
    logits, caches, pos, _ = model.prefill(
        params, {"tokens": jnp.asarray(np.asarray(prompt)[None])},
        s_max=s_max)
    out = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(gen - 1):
        logits, caches, pos, _ = model.decode_step(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def test_ring_engages(swa):
    model, params = swa
    eng = _engine(model, params)
    stats = eng.kv.stats()
    assert stats["window_ring"] and stats["ring_full_chain"]
    assert stats["ring_tokens"] == 8           # round_up(window=8, bs=4)
    assert eng.kv.blocks_per_slot == 2         # M // bs: fixed per slot


def test_ring_matches_windowed_oracle(swa):
    """Prompt lengths straddling the window (14 > 8 > 7), none a multiple
    of chunk or block size: every greedy stream matches the one-shot
    windowed oracle token for token."""
    model, params = swa
    eng = _engine(model, params)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, SWA.vocab_size, (n,)).astype(np.int32)
               for n in (14, 11, 9, 7)]
    outputs, rep = captured_run(
        eng, [Request(rid=i, tokens=p, max_new_tokens=GEN)
              for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        assert outputs[i] == _oracle(model, params, p,
                                     eng.ecfg.max_seq_len), \
            f"rid {i} (prompt len {len(p)})"
    assert rep["state_pool"]["window_ring"]


def test_ring_preemption_resume_token_exact(swa):
    """Preempt a ring request mid-decode (its whole fixed chain is
    released), resume, and the stream is unchanged — re-prefill rebuilds
    the ring contents for prompt + committed output exactly."""
    model, params = swa
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, SWA.vocab_size, (13,)).astype(np.int32)

    eng = _engine(model, params)
    base, _ = captured_run(
        eng, [Request(rid=0, tokens=prompt, max_new_tokens=GEN)])

    eng2 = _engine(model, params)
    outputs = {}
    orig = eng2._finish

    def cap(st, now):
        outputs[st.req.rid] = list(st.output)
        orig(st, now)

    eng2._finish = cap
    eng2.submit(Request(rid=0, tokens=prompt, max_new_tokens=GEN))
    preempted = False
    while eng2.has_work():
        eng2.step(eng2.clock.now())
        if not preempted and eng2.active.any():
            s = int(np.nonzero(eng2.active)[0][0])
            st = eng2.state_by_slot[s]
            if st is not None and len(st.output) >= 3:
                eng2._preempt(st)
                preempted = True
    assert preempted
    assert outputs[0] == base[0]
    assert eng2.report()["state_pool"]["preemptions"] == 1


def test_ring_chains_never_grow(swa):
    """With ring_full_chain every slot's chain is allocated whole at
    admission; the block allocator sees no extends during decode."""
    model, params = swa
    eng = _engine(model, params)
    orig_extend = eng._alloc.extend
    calls = []
    eng._alloc.extend = lambda rid: calls.append(rid) or orig_extend(rid)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, SWA.vocab_size, (14,)).astype(np.int32)
    captured_run(eng, [Request(rid=0, tokens=prompt, max_new_tokens=GEN)])
    assert calls == []


@pytest.mark.parametrize("kw,frag", [
    (dict(speculative_k=2), "single-query"),
    (dict(prefix_sharing=True), "absolute sequence length"),
    (dict(fused_paged_attention=True), "no ring arithmetic"),
    (dict(role="prefill"), "handoff"),
], ids=["speculative", "sharing", "fused", "role"])
def test_ring_blockers_rejected(swa, kw, frag):
    model, params = swa
    with pytest.raises(ValueError, match=frag):
        _engine(model, params, **kw)


def test_chunk_wider_than_ring_rejected(swa):
    model, params = swa
    with pytest.raises(ValueError, match="chunk"):
        engine_config_for(SWA, max_slots=2, prompt_len=L_MAX,
                          max_new_tokens=GEN, prefill_chunk=16,
                          paged=True, kv_block_size=BS)


def test_slab_still_rejects_beyond_window(swa):
    """The slab pool keeps its loud rejection (its clamped cache cannot
    hold more than the window); the error now points at the paged ring."""
    with pytest.raises(ValueError, match="paged"):
        engine_config_for(SWA, max_slots=2, prompt_len=L_MAX,
                          max_new_tokens=GEN, prefill_chunk=CHUNK)
