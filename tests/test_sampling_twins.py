"""Differential tests: sample_np (host twin) vs sample_tokens (device).

The two samplers share truncation semantics but not RNGs, so the testable
contract is the *kept candidate set*: for a given (logits, temperature,
top_k, top_p) the set of tokens either sampler can ever emit must be
identical.  Tie-heavy logits and nucleus-boundary ties are exactly where
the twins used to diverge — np.argpartition keeps an arbitrary subset of
a tie straddling the k-th place and unstable argsort an arbitrary order
inside the nucleus, while jax.lax.top_k keeps the lowest indices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.sampling import sample_np, sample_tokens

N_DRAWS = 512


def _support_jax(logits, **kw):
    """Tokens the device sampler can emit: N_DRAWS independent draws in
    one batched call (categorical noise is independent per row)."""
    batch = jnp.tile(jnp.asarray(logits, jnp.float32)[None], (N_DRAWS, 1))
    out = sample_tokens(batch, jax.random.PRNGKey(0), **kw)
    return set(np.asarray(out).tolist())


def _support_np(logits, **kw):
    rng = np.random.default_rng(0)
    row = np.asarray(logits, np.float64)
    return {sample_np(row, rng, **kw) for _ in range(N_DRAWS)}


def test_top_k_tie_straddling_candidate_sets():
    """Interleaved exact ties at the top-k boundary: lax.top_k keeps the
    lowest tied indices; the host twin must keep the same set (argpartition
    used to keep an arbitrary one)."""
    logits = np.array([0., 1.] * 4)           # ties at 1.0 on odd indices
    for k in (2, 3, 4):
        kw = dict(temperature=1.0, top_k=k)
        assert _support_jax(logits, **kw) == _support_np(logits, **kw) \
            == set(range(1, 2 * k, 2))


def test_nucleus_boundary_tie_candidate_sets():
    """A tie group straddling the nucleus boundary: the kept prefix is
    defined by the descending-stable sort order, so both twins must cut
    the tie at the same indices."""
    logits = np.zeros(32)
    logits[::2] = 1.0                          # 16 tied highs, 16 tied lows
    kw = dict(temperature=1.0, top_p=0.3)      # cuts inside the tied highs
    sj, sn = _support_jax(logits, **kw), _support_np(logits, **kw)
    assert sj == sn
    # the nucleus holds the first ceil(0.3 / p_high) highs by index order
    assert sj == {0, 2, 4, 6, 8, 10, 12}


def test_top_k_then_nucleus_composition():
    """top-p applied within the top-k candidates, ties in both stages."""
    logits = np.array([0., 1.] * 8)
    kw = dict(temperature=1.0, top_k=6, top_p=0.5)
    sj, sn = _support_jax(logits, **kw), _support_np(logits, **kw)
    assert sj == sn
    assert sj <= {1, 3, 5, 7, 9, 11}           # within the top-k tie set


def test_generic_logits_candidate_sets():
    """No ties: the twins must agree on plain margins too."""
    rng = np.random.default_rng(3)
    logits = rng.normal(size=24)
    for kw in (dict(temperature=0.7, top_k=5),
               dict(temperature=1.3, top_p=0.8),
               dict(temperature=1.0, top_k=8, top_p=0.6)):
        assert _support_jax(logits, **kw) == _support_np(logits, **kw)


def test_greedy_tie_break_matches():
    logits = np.array([1., 3., 3., 0.])
    assert int(np.asarray(sample_tokens(jnp.asarray(logits)[None],
                                        None))[0]) == 1
    assert sample_np(logits, None) == 1
