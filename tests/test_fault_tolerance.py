"""Fault tolerance drill: kill training at step N, restart, and verify the
resumed run reaches the same final state as an uninterrupted run."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _train(tmp, steps, fail_at=None, seed=0):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if fail_at is not None:
        env["REPRO_FAIL_AT_STEP"] = str(fail_at)
    else:
        env.pop("REPRO_FAIL_AT_STEP", None)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "stablelm-1.6b", "--reduced", "--steps", str(steps), "--batch",
           "2", "--seq-len", "16", "--ckpt-dir", tmp, "--ckpt-every", "4",
           "--log-every", "4", "--seed", str(seed)]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=900)


def test_injected_failure_then_resume(tmp_path):
    d1 = str(tmp_path / "interrupted")
    # run 1: dies at step 10 (after the step-8 checkpoint committed)
    r = _train(d1, steps=16, fail_at=10)
    assert r.returncode != 0
    assert "injected failure" in (r.stdout + r.stderr)
    # restart: resumes from the last committed checkpoint and finishes
    r2 = _train(d1, steps=16)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step" in r2.stdout

    # uninterrupted reference run
    d2 = str(tmp_path / "clean")
    r3 = _train(d2, steps=16)
    assert r3.returncode == 0

    # final checkpoints agree bit-exactly (deterministic data + resume)
    a = np.load(os.path.join(d1, "step_16", "leaf_0.npy"))
    b = np.load(os.path.join(d2, "step_16", "leaf_0.npy"))
    np.testing.assert_array_equal(a, b)


def test_loss_improves_over_training(tmp_path):
    r = _train(str(tmp_path / "ck"), steps=30)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "improved" in r.stdout and "NOT improved" not in r.stdout, r.stdout
