"""Mamba2/SSD correctness: chunked scan vs naive recurrence, chunk-size
independence, and prefill->decode state continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback sampler
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs import get_config
from repro.models.mamba2 import (init_mamba, init_state, mamba_block,
                                 ssd_chunked, ssd_recurrent_ref)


def _inputs(seed, B, L, H, P, N):
    key = jax.random.PRNGKey(seed)
    xh = jax.random.normal(jax.random.fold_in(key, 0), (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, L, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bs = jax.random.normal(jax.random.fold_in(key, 3), (B, L, N))
    Cs = jax.random.normal(jax.random.fold_in(key, 4), (B, L, N))
    return xh, dt, A, Bs, Cs


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([7, 16, 37]),
       st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrence(seed, L, chunk):
    xh, dt, A, Bs, Cs = _inputs(seed, 2, L, 3, 4, 8)
    y1, s1 = ssd_chunked(xh, dt, A, Bs, Cs, chunk=chunk)
    y2, s2 = ssd_recurrent_ref(xh, dt, A, Bs, Cs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_resume():
    """Splitting a sequence in two with a carried state == one pass."""
    xh, dt, A, Bs, Cs = _inputs(0, 1, 32, 2, 4, 8)
    y_full, s_full = ssd_chunked(xh, dt, A, Bs, Cs, chunk=8)
    y1, s1 = ssd_chunked(xh[:, :16], dt[:, :16], A, Bs[:, :16], Cs[:, :16],
                         chunk=8)
    y2, s2 = ssd_chunked(xh[:, 16:], dt[:, 16:], A, Bs[:, 16:], Cs[:, 16:],
                         chunk=8, init_ssm=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


def test_mamba_block_prefill_then_decode_matches_full():
    """Block-level: prefill S tokens + decode 1 == full S+1 forward."""
    cfg = get_config("mamba2-2.7b").reduced().replace(dtype="float32")
    p = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 1, 13
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.3
    y_full, _ = mamba_block(x, p, cfg, state=init_state(B, cfg))
    y1, st = mamba_block(x[:, :S], p, cfg, state=init_state(B, cfg))
    y2, _ = mamba_block(x[:, S:], p, cfg, state=st)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, S:]),
                               atol=1e-4, rtol=1e-3)
