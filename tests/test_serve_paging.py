"""Paged KV pool tests: the block allocator, the chunk-to-block scatter,
and the block-aware engine — admission gated on free blocks, incremental
chain growth, preemption-by-recompute, and the equal-memory concurrency
win over the slab pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import build_model
from repro.serve import (BlockAllocator, Request, ServeEngine, VirtualClock,
                         blocks_for_tokens, engine_config_for,
                         make_paged_pool, poisson_requests,
                         write_chunk_blocks)
from repro.serve.slots import discover_seq_axes

from _serve_helpers import captured_run

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=16, dtype="float32")


def _model(cfg, batch, seq_len):
    m = build_model(cfg, ParallelConfig(attn_chunk=8, loss_chunk=8),
                    batch=batch, seq_len=seq_len)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(model, params, *, slots, prompt_len, max_new, chunk, **kw):
    ecfg = engine_config_for(model.cfg, max_slots=slots,
                             prompt_len=prompt_len, max_new_tokens=max_new,
                             prefill_chunk=chunk, **kw)
    return ServeEngine(model, params, ecfg, clock=VirtualClock(0.1))


# ----------------------------------------------------------------------
# allocator
# ----------------------------------------------------------------------
def test_block_allocator_alloc_extend_release():
    a = BlockAllocator(num_blocks=6, block_size=4)    # block 0 reserved
    assert a.usable_blocks == 5 and a.free_blocks == 5
    c1 = a.alloc_chain(1, 2)
    assert c1 is not None and len(c1) == 2 and 0 not in c1
    assert a.blocks_in_use == 2
    assert a.alloc_chain(2, 4) is None                # only 3 free: no-op
    assert a.free_blocks == 3
    c2 = a.alloc_chain(2, 3)
    assert a.free_blocks == 0
    assert a.extend(1) is None                        # dry
    assert a.release(2) == 3
    blk = a.extend(1)
    assert blk in c2                                  # recycled
    assert a.chain(1) == tuple(c1) + (blk,)
    assert a.release(1) == 3 and a.free_blocks == 5
    assert a.alloc_chain(3, 1) is not None
    with pytest.raises(ValueError, match="already holds"):
        a.alloc_chain(3, 1)                           # double alloc same rid


def test_blocks_for_tokens():
    assert blocks_for_tokens(1, 4) == 1
    assert blocks_for_tokens(4, 4) == 1
    assert blocks_for_tokens(5, 4) == 2


# ----------------------------------------------------------------------
# physical pool + chunk scatter (structural, fake cache layouts)
# ----------------------------------------------------------------------
def _fake_init_cache(b, s_max):
    """Scan-stacked blocks (batch axis 1, seq axis 2) + an unscanned lead
    layer (batch axis 0, seq axis 1) — full-length KV on every leaf."""
    return {
        "blocks": (jnp.zeros((3, b, s_max, 2, 4)),
                   jnp.zeros((3, b, s_max, 2, 4))),
        "lead": [jnp.zeros((b, s_max, 2, 4))],
    }


def test_make_paged_pool_resizes_seq_axis():
    seq = discover_seq_axes(_fake_init_cache, 16)
    pool = make_paged_pool(_fake_init_cache, 16, seq, num_blocks=5,
                           block_size=4)
    assert pool["blocks"][0].shape == (3, 1, 20, 2, 4)
    assert pool["lead"][0].shape == (1, 20, 2, 4)


def test_make_paged_pool_rejects_clamped_and_seqless_leaves():
    def clamped(b, s):
        return {"kv": jnp.zeros((b, min(s, 6), 2, 4))}   # window ring buffer

    with pytest.raises(NotImplementedError, match="pageable"):
        make_paged_pool(clamped, 16, discover_seq_axes(clamped, 16), 4, 4)

    def seqless(b, s):
        return {"kv": jnp.zeros((b, s, 2, 4)), "state": jnp.zeros((b, 8))}

    with pytest.raises(NotImplementedError, match="pageable"):
        make_paged_pool(seqless, 16, discover_seq_axes(seqless, 16), 4, 4)


def test_write_chunk_blocks_scatters_through_table():
    """Chunk [start, start+C) of the scratch lands at the block-translated
    physical positions; everything else in the pool stays untouched."""
    bs, C, s_max = 4, 4, 16
    seq = discover_seq_axes(_fake_init_cache, s_max)
    pool = make_paged_pool(_fake_init_cache, s_max, seq, num_blocks=6,
                           block_size=bs)
    # scratch leaf value = logical position + 1 along the seq axis
    def fill(leaf, ax):
        r = jnp.arange(1, s_max + 1, dtype=leaf.dtype)
        shape = [1] * leaf.ndim
        shape[ax] = s_max
        return jnp.broadcast_to(r.reshape(shape), leaf.shape)
    scratch = jax.tree.map(fill, _fake_init_cache(1, s_max), seq)

    bt_row = np.zeros((4,), np.int32)
    bt_row[:2] = [3, 1]        # logical block 0 -> phys 3, block 1 -> phys 1
    out = jax.jit(lambda p, s, r, st: write_chunk_blocks(
        p, s, r, st, chunk=C, block_size=bs, seq_axes=seq))(
            pool, scratch, bt_row, np.int32(4))    # second logical chunk
    lead = np.asarray(out["lead"][0])              # [1, 24, 2, 4]
    # logical positions 4..7 (values 5..8) live in physical block 1
    assert (lead[0, 4:8, 0, 0] == np.arange(5, 9)).all()
    # physical block 3 (logical block 0's home) untouched by this chunk
    assert (lead[0, 12:16] == 0).all()
    stacked = np.asarray(out["blocks"][0])         # [3, 1, 24, 2, 4]
    assert (stacked[:, 0, 4:8, 0, 0] == np.arange(5, 9)).all()


# ----------------------------------------------------------------------
# block-aware engine
# ----------------------------------------------------------------------
def test_paged_recycling_zero_recompilation():
    """Six requests through two slots on a paged pool: admission, chain
    growth, EOS reclamation, and slot recycling never add a jit entry."""
    L, gen, slots = 8, 4, 2
    model, params = _model(TINY, slots, L)
    eng = _engine(model, params, slots=slots, prompt_len=L, max_new=gen,
                  chunk=4, paged=True, kv_block_size=4)
    reqs = poisson_requests(6, rate=0.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=0)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 6
    assert rep["total_new_tokens"] == 6 * gen
    used = [s for _, s in eng.slot_history]
    assert sorted(set(used)) == [0, 1] and max(np.bincount(used)) >= 2
    assert rep["jit_entries"] == {"prefill_chunk": 1, "decode": 1,
                                  "write_blocks": 1}, rep["jit_entries"]
    # all blocks reclaimed once the pool drains
    assert eng._alloc.blocks_in_use == 0
    assert (eng.block_table == 0).all()
    assert 0 < rep["kv_utilization"] <= 1.0


def test_preemption_by_recompute_is_token_exact():
    """A block budget too small for every admitted request forces
    preemption; the preempted request is recomputed and still emits exactly
    its solo greedy stream, with zero recompilation."""
    L, gen = 8, 8
    model, params = _model(TINY, 3, L)

    def mk():
        rng = np.random.default_rng(3)
        return [Request(rid=i,
                        tokens=rng.integers(0, TINY.vocab_size,
                                            (L,)).astype(np.int32),
                        max_new_tokens=gen) for i in range(5)]

    reqs_a, reqs_b = mk(), mk()

    solo = _engine(model, params, slots=1, prompt_len=L, max_new=gen,
                   chunk=4)
    out_ref, _ = captured_run(solo, reqs_a)
    # worst case 16 tokens = 4 blocks/request; 6 usable blocks for 3 slots
    eng = _engine(model, params, slots=3, prompt_len=L, max_new=gen,
                  chunk=4, paged=True, kv_block_size=4, num_kv_blocks=6)
    out, rep = captured_run(eng, reqs_b)
    assert rep["preemptions"] > 0
    assert rep["n_requests"] == 5
    for rid in out_ref:
        assert out[rid] == out_ref[rid], rid
    assert rep["jit_entries"] == {"prefill_chunk": 1, "decode": 1,
                                  "write_blocks": 1}
    assert eng._alloc.blocks_in_use == 0     # everything reclaimed


def test_admission_gated_on_free_blocks():
    """With blocks for only one worst-case request, a second request waits
    even though a slot is free — admission is block-aware, not slot-aware."""
    L, gen = 8, 4
    model, params = _model(TINY, 2, L)
    eng = _engine(model, params, slots=2, prompt_len=L, max_new=gen,
                  chunk=4, paged=True, kv_block_size=4, num_kv_blocks=3)
    reqs = poisson_requests(2, rate=0.0, vocab_size=TINY.vocab_size,
                            prompt_len=L, max_new_tokens=gen, seed=1)
    rep = eng.run(reqs)
    assert rep["n_requests"] == 2            # both finish eventually
    assert rep["max_occupancy"] == 1         # but never decode together
    assert rep["preemptions"] == 0           # waiting, not thrashing


def test_paged_outlives_slab_at_equal_memory():
    """Equal KV token budget, mixed prompt lengths: the paged engine
    decodes strictly more requests concurrently than the slab pool's
    worst-case slot count allows."""
    gen, C = 6, 4
    max_prompt = 16
    model, params = _model(TINY, 8, max_prompt)
    # slab: 2 slots x (16 + 6 -> padded 24) = 48 KV tokens reserved
    slab = _engine(model, params, slots=2, prompt_len=max_prompt,
                   max_new=gen, chunk=C)
    budget = 2 * slab.ecfg.max_seq_len
    # paged: same 48 tokens as 12 4-token blocks, decode width 8
    paged = _engine(model, params, slots=8, prompt_len=max_prompt,
                    max_new=gen, chunk=C, paged=True, kv_block_size=4,
                    num_kv_blocks=budget // 4)
    reqs = poisson_requests(8, rate=0.0, vocab_size=TINY.vocab_size,
                            prompt_len=max_prompt, max_new_tokens=gen,
                            seed=2, prompt_len_range=(4, 8))
    rep_s = slab.run(list(reqs))
    rep_p = paged.run(list(reqs))
    assert rep_s["n_requests"] == rep_p["n_requests"] == 8
    assert rep_p["max_occupancy"] > rep_s["max_occupancy"]
    assert rep_p["max_occupancy"] > 2        # beyond the slab's hard cap
    assert rep_p["decode_steps"] < rep_s["decode_steps"]


def test_paged_rejects_window_clamped_cache():
    """A model whose sliding window binds below the padded pool length
    cannot be paged (the paged decode path is window-free) —
    engine_config_for rejects the shapes with an actionable error, and a
    hand-built EngineConfig that sneaks past it is rejected at engine
    construction with a clear window error (not just the late structural
    leaf rejection)."""
    from repro.serve import EngineConfig
    cfg = TINY.replace(sliding_window=8)
    model, params = _model(cfg, 1, 16)
    # max_seq_len 16+8=24 > window 8 -> leaf clamped -> not pageable
    with pytest.raises(ValueError, match="sliding window"):
        engine_config_for(cfg, max_slots=1, prompt_len=8,
                          max_new_tokens=16, prefill_chunk=8,
                          paged=True, kv_block_size=4)
    with pytest.raises(ValueError, match="window-free"):
        ServeEngine(model, params,
                    EngineConfig(max_slots=1, max_seq_len=24,
                                 prefill_chunk=8, paged=True,
                                 kv_block_size=4))
    # a window the padded pool fits inside never binds: accepted, and the
    # engine still serves token streams (window-free == exact there)
    cfg_wide = TINY.replace(sliding_window=64)
    model_w, params_w = _model(cfg_wide, 1, 16)
    eng = ServeEngine(model_w, params_w,
                      EngineConfig(max_slots=1, max_seq_len=24,
                                   prefill_chunk=8, paged=True,
                                   kv_block_size=4))
    assert eng.blocks_per_slot == 6
    # prefix sharing pads one extra chunk: shapes that fit a window
    # without sharing are rejected with it, up front
    cfg64 = TINY.replace(sliding_window=64)
    engine_config_for(cfg64, max_slots=1, prompt_len=56, max_new_tokens=8,
                      prefill_chunk=8, paged=True, kv_block_size=4)
    with pytest.raises(ValueError, match="extra prefill chunk"):
        engine_config_for(cfg64, max_slots=1, prompt_len=56,
                          max_new_tokens=8, prefill_chunk=8, paged=True,
                          kv_block_size=4, prefix_sharing=True)


def test_paged_mixed_lengths_decode_together():
    """Different prompt lengths share one paged decode batch and each still
    reproduces its solo stream (per-row block chains + validity masks)."""
    model, params = _model(TINY, 2, 12)
    rng = np.random.default_rng(7)
    pa = rng.integers(0, TINY.vocab_size, (12,)).astype(np.int32)
    pb = rng.integers(0, TINY.vocab_size, (5,)).astype(np.int32)
    gen = 5

    def run(reqs):
        eng = _engine(model, params, slots=2, prompt_len=12, max_new=gen,
                      chunk=4, paged=True, kv_block_size=4)
        out, _ = captured_run(eng, reqs)
        return out

    together = run([Request(rid=0, tokens=pa, max_new_tokens=gen),
                    Request(rid=1, tokens=pb, max_new_tokens=gen)])
    solo_a = run([Request(rid=0, tokens=pa, max_new_tokens=gen)])
    solo_b = run([Request(rid=1, tokens=pb, max_new_tokens=gen)])
    assert together[0] == solo_a[0]
    assert together[1] == solo_b[1]
