"""Multi-device tests run in subprocesses with 8 fake host devices (the main
pytest process keeps the real single device; see conftest.py)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_moe_block_oracle_on_2x4_mesh():
    """Full HarMoEny pipeline on a (data=2, model=4) mesh matches a dense
    per-token oracle — covers metadata exchange, scheduling, all_to_all
    dispatch/combine, and the foreign-expert fetch."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.core.moe_layer import MoEBlockSpec, moe_block, init_moe_params
    from repro.core.router import route_topk
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, d, f, E, k = 4, 16, 32, 64, 8, 2
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    policy="harmoeny", capacity_factor=2.0, num_foreign_slots=4)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model",
                        batch_axes=("data",), ep_degree=4,
                        tokens_local=(B//2)*S, block_m=8, act="silu")
    params = init_moe_params(jax.random.PRNGKey(42), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y, diag = jax.jit(lambda x, p: moe_block(x, p, spec=spec, mesh=mesh))(x, params)
    assert float(diag["send_drops"].sum() + diag["dest_drops"].sum()) == 0
    from repro.core.topology import make_topology
    topo = make_topology(4, E)
    rows = np.zeros(E, np.int32)
    for g in range(4):
        for j in range(topo.experts_per_rank):
            rows[topo.slot_map[g, j]] = g * topo.experts_per_rank + j
    flat = np.asarray(x).reshape(-1, d)
    r = route_topk(jnp.asarray(flat), params["router"], top_k=k, num_real_experts=E)
    y_ref = np.zeros_like(flat)
    for t in range(flat.shape[0]):
        for j in range(k):
            e = rows[int(r.assign[t, j])]; g = float(r.gates[t, j])
            h = np.asarray(jax.nn.silu(flat[t] @ params["w_gate"][e])) * (flat[t] @ np.asarray(params["w_in"][e]))
            y_ref[t] += g * (h @ np.asarray(params["w_out"][e]))
    err = np.abs(np.asarray(y).reshape(-1, d) - y_ref).max()
    assert err < 2e-4, err
    print("OK", err)
    """)


def test_skew_balances_load_across_ranks():
    """90% router skew: the schedule's per-rank loads equalize (paper Fig 2)
    and throughput-critical drops stay zero."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.core.moe_layer import MoEBlockSpec, moe_block, init_moe_params
    mesh = make_mesh((1, 8), ("data", "model"))
    B, S, d, f, E, k = 2, 256, 16, 32, 16, 1
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    policy="harmoeny", router_skew=0.9, q_tokens=2,
                    capacity_factor=1.5, num_foreign_slots=4)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model", batch_axes=("data",),
                        ep_degree=8, tokens_local=B*S, block_m=8, act="silu")
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y, diag = jax.jit(lambda x, p: moe_block(
            x, p, spec=spec, mesh=mesh, skew_key=jax.random.PRNGKey(7)))(x, params)
    mb, ma = float(diag["max_load_before"].mean()), float(diag["max_load_after"].mean())
    drops = float(diag["send_drops"].sum() + diag["dest_drops"].sum())
    assert drops == 0, drops
    assert ma < 0.35 * mb, (mb, ma)   # near-perfect balance from ~90% skew
    assert bool(jnp.isfinite(y).all())
    print("OK", mb, "->", ma)
    """)


def test_round_robin_drops_under_skew_harmoeny_does_not():
    """The TPU-native restatement of the paper's headline: same capacity
    factor, same skew — round-robin drops tokens, HarMoEny does not."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.core.moe_layer import MoEBlockSpec, moe_block, init_moe_params
    import dataclasses
    mesh = make_mesh((1, 8), ("data", "model"))
    B, S, d, f, E, k = 2, 256, 16, 32, 16, 1
    base = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                     router_skew=0.9, q_tokens=2, capacity_factor=1.25,
                     num_foreign_slots=4)
    drops = {}
    for policy in ("round_robin", "harmoeny"):
        moe = dataclasses.replace(base, policy=policy)
        spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model",
                            batch_axes=("data",), ep_degree=8,
                            tokens_local=B*S, block_m=8, act="silu")
        params = init_moe_params(jax.random.PRNGKey(0), spec)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
        with mesh:
            _, diag = jax.jit(lambda x, p: moe_block(
                x, p, spec=spec, mesh=mesh,
                skew_key=jax.random.PRNGKey(7)))(x, params)
        drops[policy] = float(diag["send_drops"].sum() + diag["dest_drops"].sum())
    assert drops["harmoeny"] == 0, drops
    assert drops["round_robin"] > 50, drops
    print("OK", drops)
    """)


def test_seq_sharded_island_matches_replicated():
    """SP in/out specs give bit-identical results to the replicated island."""
    _run("""
    import dataclasses, numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.launch.mesh import make_mesh
    from repro.core.moe_layer import MoEBlockSpec, moe_block, init_moe_params
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, d, f, E, k = 4, 16, 32, 64, 8, 2
    moe = MoEConfig(num_experts=E, num_experts_per_tok=k, d_ff_expert=f,
                    capacity_factor=2.0, num_foreign_slots=4)
    spec = MoEBlockSpec(moe=moe, d_model=d, ep_axis="model",
                        batch_axes=("data",), ep_degree=4,
                        tokens_local=(B//2)*S, block_m=8, act="silu",
                        seq_sharded=True)
    spec_rep = dataclasses.replace(spec, seq_sharded=False)
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    with mesh:
        y1, _ = jax.jit(lambda x, p: moe_block(x, p, spec=spec, mesh=mesh))(x, params)
        y2, _ = jax.jit(lambda x, p: moe_block(x, p, spec=spec_rep, mesh=mesh))(x, params)
    err = np.abs(np.asarray(y1) - np.asarray(y2)).max()
    assert err < 1e-5, err
    print("OK", err)
    """)


def test_compressed_psum_grad_agreement():
    """int8 all-reduce with error feedback approximates the exact mean."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.optim.compress import compressed_psum
    from repro.launch.mesh import make_mesh
    from repro.core.compat import shard_map
    P = jax.sharding.PartitionSpec
    mesh = make_mesh((8,), ("data",))
    g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    def f(g):
        grads = {"w": g[0]}
        err = {"w": jnp.zeros_like(g[0])}
        out, new_err = compressed_psum(grads, err, jax.random.PRNGKey(1),
                                       axis_name="data")
        return out["w"][None]
    got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data", None),
                                out_specs=P("data", None),
                                check_vma=False))(g_global)
    want = np.asarray(g_global).mean(axis=0)
    err = np.abs(np.asarray(got)[0] - want).max()
    scale = np.abs(np.asarray(g_global)).max() / 127
    assert err < 3 * scale, (err, scale)
    print("OK", err)
    """)
