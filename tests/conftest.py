# NOTE: deliberately does NOT set --xla_force_host_platform_device_count:
# smoke tests and benches must see the real single device; distributed tests
# spawn subprocesses with their own XLA_FLAGS (see tests/test_distributed.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
