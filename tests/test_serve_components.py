"""Unit tests for the serve subsystem's non-model components: arrival
processes, the admission queue, latency metrics, and the slot pool's
structural batch-axis discovery / scatter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.arrivals import (AdmissionQueue, VirtualClock, WallClock,
                                  poisson_requests, trace_requests)
from repro.serve.metrics import RequestRecord, ServeMetrics, percentiles
from repro.serve.request import Request, RequestState
from repro.serve.slots import (discover_batch_axes, discover_seq_axes,
                               min_kv_capacity, write_slot)


# ----------------------------------------------------------------------
# arrivals
# ----------------------------------------------------------------------
def test_poisson_arrivals_monotone_and_rate_scaled():
    reqs = poisson_requests(200, rate=50.0, vocab_size=64, prompt_len=8,
                            max_new_tokens=4, seed=0)
    ts = [r.arrival_time for r in reqs]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    # mean inter-arrival ~ 1/rate (law of large numbers, loose bound)
    gaps = np.diff(ts)
    assert 0.5 / 50.0 < gaps.mean() < 2.0 / 50.0
    # rate=0 => closed batch at t=0
    batch = poisson_requests(5, rate=0.0, vocab_size=64, prompt_len=8,
                             max_new_tokens=4)
    assert all(r.arrival_time == 0.0 for r in batch)


def test_admission_queue_fifo_among_arrived():
    reqs = [Request(rid=i, tokens=np.ones(4, np.int32), arrival_time=t)
            for i, t in enumerate([0.0, 2.0, 0.0])]
    q = AdmissionQueue(reqs)
    assert q.peek_ready(0.0).rid == 0    # peek does not consume
    assert q.pop_ready(0.0).rid == 0     # FIFO among the two t=0 arrivals
    assert q.pop_ready(0.0).rid == 2
    assert q.peek_ready(1.0) is None     # rid=1 hasn't arrived yet
    assert q.pop_ready(1.0) is None
    assert q.next_arrival() == 2.0
    assert q.pop_ready(2.5).rid == 1
    assert len(q) == 0


def test_trace_requests_roundtrip():
    recs = [{"arrival_time": 0.5, "prompt_len": 6, "max_new_tokens": 3},
            {"arrival_time": 1.5, "tokens": [1, 2, 3], "rid": 9}]
    reqs = trace_requests(recs, vocab_size=64)
    assert reqs[0].prompt_len == 6 and reqs[0].arrival_time == 0.5
    assert reqs[1].rid == 9 and list(reqs[1].tokens) == [1, 2, 3]


def test_virtual_clock_advances():
    c = VirtualClock(0.25)
    assert c.now() == 0.25 and c.now() == 0.5
    c.wait(1.0)
    assert c.now() == pytest.approx(1.75)


def test_clocks_reset_to_zero():
    """Both clocks rebase to their origin so a measurement window can start
    at t=0 regardless of time burned before it (warmup, previous runs)."""
    v = VirtualClock(0.5)
    v.wait(100.0)
    v.reset()
    assert v.now() == 0.5

    w = WallClock()
    w.wait(0.05)
    before = w.now()
    assert before >= 0.05
    w.reset()
    # post-reset reading restarts from 0: strictly below the pre-reset
    # elapsed time (loose bound — immune to CI scheduling hiccups)
    assert w.now() < before


def test_request_validation_rejects_empty():
    with pytest.raises(ValueError):
        Request(rid=0, tokens=np.zeros((0,), np.int32))
    with pytest.raises(ValueError):
        Request(rid=0, tokens=np.ones(4, np.int32), max_new_tokens=0)


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------
def test_percentiles_and_report():
    assert percentiles([1.0, 1.0, 1.0])["p50"] == 1.0
    assert np.isnan(percentiles([])["p99"])

    m = ServeMetrics()
    st = RequestState(
        req=Request(rid=1, tokens=np.ones(4, np.int32), max_new_tokens=4,
                    arrival_time=1.0),
        slot=0, admitted_time=2.0, first_token_time=3.0, finish_time=6.0)
    st.output.extend([5, 6, 7, 8])
    rec = m.complete(st)
    assert rec.ttft == 2.0
    assert rec.tpot == pytest.approx(1.0)        # 3 intervals over 3s
    assert rec.e2e == 5.0
    m.record_step({"moved_units": 3.0}, 2, phase="decode")
    m.record_step({"moved_units": 1.0}, 2, phase="decode")
    rep = m.report()
    assert rep["moe"]["decode/moved_units"] == 2.0
    assert rep["decode_steps"] == 2 and rep["mean_occupancy"] == 2.0
    assert rep["throughput_tok_s"] == pytest.approx(4 / 5.0)


def test_tpot_degenerate_single_token():
    rec = RequestRecord(rid=0, prompt_len=4, n_generated=1, arrival_time=0.0,
                        admitted_time=0.0, first_token_time=1.0,
                        finish_time=1.0)
    assert rec.tpot == 0.0


def test_kv_metrics_and_empty_report_json_safe():
    import json

    m = ServeMetrics()
    rep = m.report()                     # empty window: None, never NaN
    assert rep["ttft"]["p50"] is None and rep["throughput_tok_s"] is None
    json.dumps(rep, allow_nan=False)

    m.record_step({}, 3, phase="decode")
    m.record_kv(6, 8)
    m.record_kv(2, 8)
    m.preemptions += 1
    rep = m.report()
    assert rep["kv_blocks_in_use"] == {"mean": 4.0, "max": 6}
    assert rep["kv_utilization"] == pytest.approx(0.5)
    assert rep["preemptions"] == 1 and rep["max_occupancy"] == 3


# ----------------------------------------------------------------------
# sampling
# ----------------------------------------------------------------------
def test_sample_tokens_and_np_greedy_paths():
    import jax

    from repro.serve import sample_np, sample_tokens
    logits = np.array([[0.0, 3.0, 1.0], [2.0, -1.0, 0.5]], np.float32)
    # greedy: no key / zero temperature
    assert list(sample_tokens(jnp.asarray(logits), None)) == [1, 0]
    assert sample_np(logits[0], None) == 1
    # top_k=1 at any temperature is still the argmax
    key = jax.random.PRNGKey(0)
    out = sample_tokens(jnp.asarray(logits), key, temperature=2.0, top_k=1)
    assert list(np.asarray(out)) == [1, 0]
    rng = np.random.default_rng(0)
    assert sample_np(logits[0], rng, temperature=2.0, top_k=1) == 1
    # full-vocab sampling stays within the simplex support
    draws = {int(x) for x in np.asarray(sample_tokens(
        jnp.asarray(np.tile(logits[0], (64, 1))), key, temperature=5.0))}
    assert draws <= {0, 1, 2} and len(draws) > 1
    # oversized top_k clamps to the vocab instead of crashing
    out = sample_tokens(jnp.asarray(logits), key, temperature=1.0, top_k=99)
    assert all(0 <= int(t) < 3 for t in np.asarray(out))
    assert 0 <= sample_np(logits[0], rng, temperature=1.0, top_k=99) < 3


# ----------------------------------------------------------------------
# slot pool
# ----------------------------------------------------------------------
def _fake_init_cache(b, s_max):
    """Mimics the real cache layout: scan-stacked blocks (batch at axis 1)
    plus unscanned lead layers (batch at axis 0)."""
    return {
        "stack": {
            "blocks": {"sub0": (jnp.zeros((3, b, s_max, 2, 4)),
                                jnp.zeros((3, b, s_max, 2, 4)))},
            "lead": [jnp.zeros((b, min(s_max, 6), 2, 4))],
        },
    }


def test_discover_batch_axes_and_capacity():
    axes = discover_batch_axes(_fake_init_cache, 16)
    assert axes["stack"]["blocks"]["sub0"] == (1, 1)
    assert axes["stack"]["lead"] == [0]
    seq = discover_seq_axes(_fake_init_cache, 16)
    assert seq["stack"]["blocks"]["sub0"] == (2, 2)
    # window-clamped leaf: s_max-invariant at (16, 17), found at (1, 2)
    assert seq["stack"]["lead"] == [1]
    # lead layer clamps its KV length to 6 (sliding-window analogue)
    assert min_kv_capacity(_fake_init_cache, 16, seq) == 6


def test_seq_axis_not_adjacent_to_batch():
    """The KV-length axis is discovered structurally, never assumed to sit
    right after the batch axis; seq-independent leaves (SSM-state analogue)
    impose no capacity."""
    def init_cache(b, s):
        return {
            "kv": jnp.zeros((3, b, 2, s, 4)),    # batch at 1, seq at 3
            "state": jnp.zeros((b, 8)),          # no seq axis at all
        }

    seq = discover_seq_axes(init_cache, 16)
    assert seq["kv"] == 3
    assert seq["state"] == -1
    assert min_kv_capacity(init_cache, 16, seq) == 16

    def no_seq(b, s):
        return {"state": jnp.zeros((b, 8))}
    with pytest.raises(ValueError, match="s_max"):
        min_kv_capacity(no_seq, 16, discover_seq_axes(no_seq, 16))


def test_write_slot_scatters_one_row():
    axes = discover_batch_axes(_fake_init_cache, 8)
    pool = jax.tree.map(lambda l: l, _fake_init_cache(4, 8))
    scratch = jax.tree.map(jnp.ones_like, _fake_init_cache(1, 8))
    out = jax.jit(lambda p, s, i: write_slot(p, s, i, axes))(
        pool, scratch, jnp.int32(2))
    k = np.asarray(out["stack"]["blocks"]["sub0"][0])
    assert (k[:, 2] == 1).all() and (k[:, [0, 1, 3]] == 0).all()
    lead = np.asarray(out["stack"]["lead"][0])
    assert (lead[2] == 1).all() and (lead[[0, 1, 3]] == 0).all()


def test_discover_batch_axes_rejects_ambiguous():
    def bad(b, s):
        return {"x": jnp.zeros((4, 4))}          # batch never appears
    with pytest.raises(ValueError):
        discover_batch_axes(bad, 8)


# ----------------------------------------------------------------------
# top-p (nucleus) sampling
# ----------------------------------------------------------------------
def test_nucleus_mask_keeps_smallest_covering_set():
    from repro.serve.sampling import nucleus_mask
    probs = jnp.array([[0.5, 0.3, 0.15, 0.05]])       # sorted descending
    assert np.asarray(nucleus_mask(probs, 0.4)).tolist() == [[True, False,
                                                              False, False]]
    assert np.asarray(nucleus_mask(probs, 0.5 + 1e-6)).tolist() == \
        [[True, True, False, False]]
    assert np.asarray(nucleus_mask(probs, 0.91)).tolist() == \
        [[True, True, True, False]]
    assert np.asarray(nucleus_mask(probs, 1.0)).all()
    # the top token survives even a tiny top_p
    assert np.asarray(nucleus_mask(probs, 1e-9))[0, 0]


def test_top_p_restricts_support_and_matches_renormalized_probs():
    from repro.serve.sampling import sample_tokens
    # softmax of these logits ~ [0.64, 0.24, 0.09, 0.03, ...]: top_p=0.7
    # keeps exactly tokens {0, 1}
    logits = jnp.array([[4.0, 3.0, 2.0, 1.0, 0.0, -50.0]])
    draws = np.array([
        int(sample_tokens(logits, jax.random.PRNGKey(i), temperature=1.0,
                          top_p=0.7)[0]) for i in range(300)])
    assert set(draws) == {0, 1}
    # renormalized within the nucleus: P(0)/P(1) = e
    frac0 = (draws == 0).mean()
    assert 0.62 < frac0 < 0.84                        # e/(1+e) ~ 0.73
    # a tiny nucleus degenerates to greedy
    draws1 = {int(sample_tokens(logits, jax.random.PRNGKey(i),
                                temperature=1.0, top_p=1e-9)[0])
              for i in range(20)}
    assert draws1 == {0}


def test_top_p_one_is_draw_exact_with_plain_sampling():
    """top_p=1.0 bypasses the nucleus path entirely: identical draws to
    the pre-top-p sampler for the same key, with and without top_k."""
    from repro.serve.sampling import sample_tokens
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    for k in (0, 5):
        for i in range(10):
            key = jax.random.PRNGKey(i)
            a = sample_tokens(logits, key, temperature=0.8, top_k=k)
            b = sample_tokens(logits, key, temperature=0.8, top_k=k,
                              top_p=1.0)
            assert (np.asarray(a) == np.asarray(b)).all()
    # and temperature=0 stays greedy regardless of top_p
    g = sample_tokens(logits, jax.random.PRNGKey(0), temperature=0.0,
                      top_p=0.3)
    assert (np.asarray(g) == np.asarray(logits).argmax(-1)).all()


def test_sample_np_top_p_matches_jit_semantics():
    from repro.serve.sampling import sample_np
    logits = np.array([4.0, 3.0, 2.0, 1.0, 0.0, -50.0])
    rng = np.random.default_rng(1)
    draws = np.array([sample_np(logits, rng, temperature=1.0, top_p=0.7)
                      for _ in range(300)])
    assert set(draws) == {0, 1}
    assert 0.62 < (draws == 0).mean() < 0.84
    # top_p=1.0 is draw-exact with the legacy path (same rng stream)
    a = [sample_np(logits, np.random.default_rng(2), temperature=0.9,
                   top_k=3) for _ in range(5)]
    b = [sample_np(logits, np.random.default_rng(2), temperature=0.9,
                   top_k=3, top_p=1.0) for _ in range(5)]
    assert a == b
    # nucleus composes inside the top-k candidates
    d = {sample_np(logits, rng, temperature=1.0, top_k=4, top_p=0.7)
         for _ in range(100)}
    assert d == {0, 1}
    assert sample_np(logits, rng, temperature=1.0, top_p=1e-9) == 0


def test_engine_config_validates_top_p():
    from repro.serve import EngineConfig
    with pytest.raises(ValueError, match="top_p"):
        EngineConfig(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        EngineConfig(top_p=1.5)
    EngineConfig(top_p=0.9)                           # fine


def test_poisson_requests_shared_prefix():
    reqs = poisson_requests(5, rate=0.0, vocab_size=64, prompt_len=12,
                            max_new_tokens=4, seed=0, shared_prefix_len=8,
                            prompt_len_range=(6, 12))
    ref = max(reqs, key=lambda r: r.prompt_len).tokens
    for r in reqs:
        k = min(8, r.prompt_len)
        assert (r.tokens[:k] == ref[:k]).all()
    # fixed-length batch: prefixes identical, tails still differ somewhere
    full = poisson_requests(5, rate=0.0, vocab_size=64, prompt_len=12,
                            max_new_tokens=4, seed=1, shared_prefix_len=8)
    for r in full[1:]:
        assert (r.tokens[:8] == full[0].tokens[:8]).all()
    assert any((a.tokens[8:] != b.tokens[8:]).any()
               for a in full for b in full if a.rid != b.rid)
