"""End-to-end serving under heavy expert skew (paper §5.2's scenario).

Serves a reduced Mixtral-family MoE through the repro.serve
continuous-batching engine — Poisson arrivals admitted into freed decode
slots, chunked prefill interleaved with decode — comparing HarMoEny and
round-robin token scheduling under a 90%-hot router, then re-serving the
same workload with a shared system prompt off the paged prefix-sharing KV
cache. Prints per-request TTFT/TPOT percentiles, decode throughput,
schedule diagnostics, and prefix-cache hit metrics.

  PYTHONPATH=src python examples/serve_skewed.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses                                            # noqa: E402

import jax                                                    # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.configs.base import ParallelConfig                 # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models.model import MeshShape, build_model         # noqa: E402
from repro.serve import (ServeEngine, engine_config_for,      # noqa: E402
                         poisson_requests)

PROMPT_LEN, GEN, SLOTS, N_REQ, RATE, SKEW = 64, 8, 4, 8, 50.0, 0.9


def run_policy(policy: str, *, prompt_len: int = PROMPT_LEN,
               prefill_chunk: int = 0, prefix_sharing: bool = False,
               shared_prefix_len: int = 0):
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, router_skew=SKEW, policy=policy))
    mesh = make_host_mesh(data=1, model=4)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=PROMPT_LEN),
                        batch=SLOTS, seq_len=PROMPT_LEN,
                        mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params,
        engine_config_for(cfg, max_slots=SLOTS, prompt_len=prompt_len,
                          max_new_tokens=GEN, skew_seed=1,
                          prefill_chunk=prefill_chunk,
                          paged=prefix_sharing, kv_block_size=16,
                          prefix_sharing=prefix_sharing),
        mesh=mesh)
    engine.warmup()
    reqs = poisson_requests(N_REQ, rate=RATE, vocab_size=cfg.vocab_size,
                            prompt_len=prompt_len, max_new_tokens=GEN,
                            seed=0, shared_prefix_len=shared_prefix_len)
    return engine.run(reqs)


def main():
    for policy in ("round_robin", "harmoeny"):
        print(f"=== policy: {policy} ===")
        rep = run_policy(policy)
        moe = rep.get("moe", {})
        drops = moe.get("prefill/send_drops", 0) \
            + moe.get("prefill/dest_drops", 0)
        print(f"  TTFT p50 {rep['ttft']['p50'] * 1e3:8.1f} ms  "
              f"p99 {rep['ttft']['p99'] * 1e3:8.1f} ms")
        print(f"  TPOT p50 {rep['tpot']['p50'] * 1e3:8.2f} ms   "
              f"decode {rep['throughput_tok_s']:.1f} tok/s")
        print(f"  prefill schedule: moved={moe.get('prefill/moved_units', 0):.0f} "
              f"drops={drops:.0f} max_load "
              f"{moe.get('prefill/max_load_before', 0):.0f}->"
              f"{moe.get('prefill/max_load_after', 0):.0f}")
    # a shared system prompt served off the paged prefix-sharing KV cache:
    # most prefill tokens come from the cache.  Shapes sized to the reduced
    # model's 64-token sliding window — paged mode needs every layer's KV
    # at full length, and sharing pads the logical pool by one extra chunk
    print("=== harmoeny + prefix-sharing KV cache (shared system prompt) ===")
    rep = run_policy("harmoeny", prompt_len=48, prefill_chunk=8,
                     prefix_sharing=True, shared_prefix_len=32)
    print(f"  TTFT p50 {rep['ttft']['p50'] * 1e3:8.1f} ms  "
          f"p99 {rep['ttft']['p99'] * 1e3:8.1f} ms")
    print(f"  prefix cache: hit_rate={rep['prefix_hit_rate']:.2f} "
          f"cow_copies={rep['cow_copies']} evictions={rep['evictions']} "
          f"prefill_chunks={rep['prefill_chunks']}")


if __name__ == "__main__":
    main()
