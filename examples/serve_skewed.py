"""End-to-end serving under heavy expert skew (paper §5.2's scenario).

Serves a reduced Mixtral-family MoE with batched requests through prefill +
decode, comparing HarMoEny and round-robin token scheduling under a 90%-hot
router. Prints TTFT, decode throughput, and schedule diagnostics.

  PYTHONPATH=src python examples/serve_skewed.py
"""
import subprocess
import sys
import os

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

for policy in ("round_robin", "harmoeny"):
    print(f"=== policy: {policy} ===")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mixtral-8x7b",
         "--reduced", "--batch", "4", "--prompt-len", "64", "--gen", "8",
         "--skew", "0.9", "--policy", policy, "--model-par", "4",
         "--data-par", "1"],
        env=env, check=True)
