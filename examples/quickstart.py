"""Quickstart: the HarMoEny MoE block in 60 lines.

Routes a skewed batch through a small MoE layer with the paper's scheduler,
prints the schedule diagnostics (the paper's headline: near-perfect balance,
zero drops), and compares against round-robin.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.moe_layer import MoEBlockSpec, init_moe_params, moe_block
from repro.launch.mesh import make_mesh

B, S, D_MODEL, D_FF = 4, 128, 64, 128
NUM_EXPERTS, TOP_K = 16, 2

mesh = make_mesh((1, 4), ("data", "model"))

for policy in ("round_robin", "harmoeny"):
    moe = MoEConfig(
        num_experts=NUM_EXPERTS,
        num_experts_per_tok=TOP_K,
        d_ff_expert=D_FF,
        policy=policy,
        router_skew=0.9,          # paper §5.1.2: 90% of tokens -> 1 expert
        q_tokens=4,
        capacity_factor=1.5,
        num_foreign_slots=4,
    )
    spec = MoEBlockSpec(moe=moe, d_model=D_MODEL, ep_axis="model",
                        batch_axes=(), ep_degree=4, tokens_local=B * S,
                        block_m=16, act="silu")
    params = init_moe_params(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D_MODEL))

    with mesh:
        y, diag = jax.jit(lambda x, p: moe_block(
            x, p, spec=spec, mesh=mesh,
            skew_key=jax.random.PRNGKey(7)))(x, params)

    print(f"policy={policy:12s} out={tuple(y.shape)} "
          f"finite={bool(jnp.isfinite(y).all())} "
          f"moved={float(diag['moved_units'].mean()):6.0f} "
          f"max_load {float(diag['max_load_before'].mean()):5.0f}"
          f" -> {float(diag['max_load_after'].mean()):5.0f} "
          f"drops={float(diag['send_drops'].sum() + diag['dest_drops'].sum()):.0f}")
