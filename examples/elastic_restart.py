"""Fault-tolerance / elasticity drill:

1. train with an injected failure at step 12 (simulated node loss),
2. restart -> auto-resume from the last committed checkpoint,
3. restore the final checkpoint onto a DIFFERENT (smaller) mesh — the
   elastic-restart path used when a pod slice is lost.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")
ckpt = tempfile.mkdtemp(prefix="harmoeny_elastic_")
env = dict(os.environ)
env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")

base = [sys.executable, "-m", "repro.launch.train", "--arch", "stablelm-1.6b",
        "--reduced", "--batch", "4", "--seq-len", "32", "--ckpt-dir", ckpt,
        "--ckpt-every", "5", "--log-every", "5", "--steps", "20"]

print("=== run 1: dies at step 12 (injected) ===")
env_fail = dict(env, REPRO_FAIL_AT_STEP="12")
r = subprocess.run(base, env=env_fail, capture_output=True, text=True)
assert r.returncode != 0 and "injected failure" in (r.stdout + r.stderr)
print("   ... crashed as planned after committing step-10 checkpoint")

print("=== run 2: restart, auto-resume, finish ===")
subprocess.run(base, env=env, check=True)

print("=== elastic restore onto a different mesh (4 fake devices) ===")
code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.models.model import build_model, MeshShape
from repro.launch import shardings as SH
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import adamw_init

cfg = get_config("stablelm-1.6b").reduced()
mesh = make_host_mesh(data=2, model=2)
ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
model = build_model(cfg, ParallelConfig(), batch=4, seq_len=32,
                    mesh_shape=ms, mesh=mesh)
with mesh:
    params = model.init(jax.random.PRNGKey(0))
    like = {{"params": params, "opt": adamw_init(params)}}
    shapes = jax.eval_shape(lambda: like)
    shard = jax.tree.map(
        lambda l: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(*([None] * len(l.shape)))),
        shapes)
    ck = Checkpointer({ckpt!r})
    step, state = ck.restore_latest(like, shardings=shard)
    print("restored step", step, "onto", mesh.devices.shape, "mesh: OK")
"""
subprocess.run([sys.executable, "-c", code], env=env, check=True)
print("elastic restart drill complete")
