"""End-to-end training driver example: train a ~small MoE (reduced moonshot
family: 64->8 experts) for a few hundred steps on CPU with checkpointing,
then verify the loss went down and a resume works.

  PYTHONPATH=src python examples/train_moe_small.py [--steps 200]
"""
import argparse
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="harmoeny_train_")
env = dict(os.environ)
env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")

half = max(args.steps // 2, 2)
base = [sys.executable, "-m", "repro.launch.train", "--arch",
        "moonshot-v1-16b-a3b", "--reduced", "--batch", "8", "--seq-len", "64",
        "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "20",
        "--dataset", "zipf"]

print(f"=== phase 1: steps 0..{half} ===")
subprocess.run(base + ["--steps", str(half)], env=env, check=True)
print(f"=== phase 2 (resumes from checkpoint): steps {half}..{args.steps} ===")
subprocess.run(base + ["--steps", str(args.steps)], env=env, check=True)
print(f"checkpoints in {ckpt}")
