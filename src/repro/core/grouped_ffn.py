"""Grouped expert FFN over the block-aligned dispatch buffer.

Reference (XLA) path: a lax.scan over block_m tiles, each tile dynamically
gathering its group's weight matrices — the XLA twin of the Pallas kernel's
grid loop. Exact compute (2*M*d*f per matmul, no per-group masked
overcompute: jax.lax.ragged_dot was rejected because its non-TPU lowering
materializes dense [G, M, f] masked intermediates — 8x compute and ~4 GB
buffers on mixtral prefill), differentiable, CPU-lowerable.

The Pallas path (kernels/moe_gmm) fuses the matmuls and double-buffers
weight tiles HBM->VMEM (the paper's async-fetch analogue one level down the
hierarchy); selected via ``use_pallas`` on TPU targets and validated in
interpret mode against this reference.

The grouped buffer rows beyond each group's real size are zeros; every
activation used here maps 0 -> 0, so padding contributes exact zeros.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _act(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "silu":
        return jax.nn.silu(x)
    raise ValueError(name)


def tile_group_map(group_sizes_padded: jnp.ndarray, n_tiles: int,
                   block_m: int) -> jnp.ndarray:
    """tile index -> group id from block-aligned group extents. Tiles beyond
    the last group clamp to the final group (their rows are zeros)."""
    offsets = jnp.cumsum(group_sizes_padded)
    starts = jnp.arange(n_tiles, dtype=jnp.int32) * block_m
    tg = jnp.searchsorted(offsets, starts, side="right").astype(jnp.int32)
    return jnp.minimum(tg, group_sizes_padded.shape[0] - 1)


def grouped_ffn_ref(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                    group_sizes_padded: jnp.ndarray, *,
                    w_gate: Optional[jnp.ndarray] = None,
                    act: str = "gelu", block_m: int = 128) -> jnp.ndarray:
    """x [M, d] (M % block_m == 0, groups block-aligned); w_in/w_gate
    [G, d, f]; w_out [G, f, d]."""
    M, d = x.shape
    n_tiles = M // block_m
    tg = tile_group_map(group_sizes_padded, n_tiles, block_m)
    xt = x.reshape(n_tiles, block_m, d)

    def step(_, inp):
        xi, g = inp
        h = xi @ w_in[g]
        if w_gate is not None:
            h = _act("silu", xi @ w_gate[g]) * h
        else:
            h = _act(act, h)
        return None, (h.astype(xi.dtype) @ w_out[g])

    _, yt = jax.lax.scan(jax.checkpoint(step), None, (xt, tg))
    return yt.reshape(M, d)


def grouped_ffn(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                group_sizes_padded: jnp.ndarray, *,
                w_gate: Optional[jnp.ndarray] = None, act: str = "gelu",
                use_pallas: bool = False, interpret: bool = False,
                block_m: int = 128) -> jnp.ndarray:
    if not use_pallas:
        return grouped_ffn_ref(x, w_in, w_out, group_sizes_padded,
                               w_gate=w_gate, act=act, block_m=block_m)
    from repro.kernels.moe_gmm.ops import fused_expert_ffn
    return fused_expert_ffn(x, w_in, w_out, group_sizes_padded,
                            w_gate=w_gate, act=act, block_m=block_m,
                            interpret=interpret)
