from repro.core.topology import EPTopology, make_topology, static_opt_placement
from repro.core.scheduler import schedule, rebalance, initial_assign, even_split
from repro.core.moe_layer import MoEBlockSpec, moe_block, init_moe_params
