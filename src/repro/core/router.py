"""Top-k MoE router + the paper's synthetic expert-popularity skew (§5.1.2).

The router is a dense linear layer producing per-expert logits; assignment is
top-k with softmax-normalized gate weights over the selected experts
(Mixtral-style; Switch top-1 is the k=1 special case).

Synthetic skew: with skew ``alpha`` and ``n_hot`` hot experts, the hot set
shares probability mass ``alpha`` and the remaining experts share ``1-alpha``
evenly; per-unit experts are sampled from that multinomial (paper §5.1.2).
This replaces the learned router in benchmarks to inject controlled imbalance.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class RouterOutput(NamedTuple):
    assign: jnp.ndarray   # [T, k] int32 expert ids
    gates: jnp.ndarray    # [T, k] float gate weights (sum to 1 across k)
    counts: jnp.ndarray   # [Ep] int32 histogram of assignments
    aux_loss: jnp.ndarray # load-balance auxiliary loss (training)


def _histogram(assign: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    return jnp.zeros((num_experts,), jnp.int32).at[assign.reshape(-1)].add(
        1, mode="drop")


def route_topk(x: jnp.ndarray, w_router: jnp.ndarray, *, top_k: int,
               num_real_experts: int) -> RouterOutput:
    """x [T, d], w_router [d, Ep] -> top-k assignment.

    Padded (dummy) experts beyond ``num_real_experts`` are masked to -inf so
    they are never selected.
    """
    T, _ = x.shape
    Ep = w_router.shape[1]
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    mask = jnp.arange(Ep) >= num_real_experts
    logits = jnp.where(mask[None, :], -jnp.inf, logits)
    top_vals, assign = jax.lax.top_k(logits, top_k)              # [T, k]
    gates = jax.nn.softmax(top_vals, axis=-1)
    counts = _histogram(assign, Ep)
    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    f = counts.astype(jnp.float32) / jnp.maximum(T * top_k, 1)
    p = probs.mean(axis=0)
    aux = num_real_experts * jnp.sum(f * p)
    return RouterOutput(assign.astype(jnp.int32), gates, counts, aux)


def route_skewed(key: jax.Array, T: int, *, top_k: int, num_experts: int,
                 padded_experts: int, alpha: float,
                 n_hot: int = 1) -> RouterOutput:
    """Paper §5.1.2 synthetic skew router (for benchmarks / ablations)."""
    hot = jnp.arange(padded_experts) < n_hot
    p_hot = alpha / n_hot
    p_cold = (1.0 - alpha) / max(num_experts - n_hot, 1)
    probs = jnp.where(hot, p_hot,
                      jnp.where(jnp.arange(padded_experts) < num_experts,
                                p_cold, 0.0))
    logits = jnp.log(jnp.maximum(probs, 1e-30))
    assign = jax.random.categorical(key, logits[None, :],
                                    shape=(T, top_k)).astype(jnp.int32)
    gates = jnp.full((T, top_k), 1.0 / top_k, jnp.float32)
    counts = _histogram(assign, padded_experts)
    return RouterOutput(assign, gates, counts, jnp.float32(0.0))
