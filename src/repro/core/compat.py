"""Compatibility shims for jax API drift across supported versions.

The repo targets current jax, but must degrade gracefully on 0.4.x (the
container toolchain): ``shard_map`` lived in ``jax.experimental`` and took
``check_rep`` instead of ``check_vma``; ``jax.sharding.AxisType`` did not
exist (see launch/mesh.py for the mesh-side shim).
"""
from __future__ import annotations

import jax


def shard_map(*args, **kwargs):
    """``jax.shard_map`` where available, else the experimental one (with
    ``check_vma`` mapped back to its old name ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return sm(*args, **kwargs)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the CompilerParams /
    TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls(**kwargs)
