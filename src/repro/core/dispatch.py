"""Schedule-driven token dispatch / combine (paper Alg. 1 steps 4 & 6).

Runs *per EP rank* inside a shard_map over the 'model' mesh axis. Both sender
and receiver derive all buffer layouts purely from the replicated schedule
``S`` and static conventions, so no index metadata is ever communicated —
only the token payloads move (plus the tiny counts all_gather done earlier).

Ordering convention (shared by both sides): the units of (source g, expert e)
are ordered by their within-expert rank r (stable sort of local units by
expert). The first S[g,e,0] of them go to destination 0, the next S[g,e,1]
to destination 1, etc. Within a pair (g -> h) chunk, units are ordered by
(e, r). Within a destination group (one expert slot), rows are ordered by
(source g, r).

Static buffers:
  * send/recv: [G, c_pair, d]  — off-diagonal pairs only; the self-pair
    bypasses the all_to_all entirely (zero wire bytes, no capacity bound);
  * grouped compute buffer: [c_total, d] with every expert-slot group
    starting at a multiple of ``block_m`` (so Pallas tiles never straddle
    groups, and padding rows are zeros).

Overflowing units are dropped *and counted* (`DispatchDiag`): with the
HarMoEny policy the scheduler bounds every load so drops stay ~0 at
capacity_factor ~1.25; round-robin under skew drops heavily — the TPU-native
restatement of the paper's latency gap (DESIGN.md §2). Units scheduled to a
rank that has no group for their expert (no local slot, no replica slot, and
no free foreign slot) are also dropped and counted into ``dest_drops``.

Replica slots: ``num_replica_slots`` static groups between the local and
foreign groups hold weight-resident copies of hot experts chosen between
serving windows (serve/rebalance.py). Which expert occupies each slot is a
*traced* int32 vector (``replica_ids_me``, -1 = empty), so re-targeting a
replica never changes shapes or recompiles. Group order in the compute
buffer: local (epr) | replica (R) | foreign (K).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import EPTopology, local_slot_of


class DispatchLayout(NamedTuple):
    """Everything both sides derive from S (per rank, inside shard_map)."""
    # sender side (per local unit)
    unit_dest: jnp.ndarray        # [U] destination rank per unit
    unit_pair_pos: jnp.ndarray    # [U] row within the (me -> dest) pair chunk
    unit_row_self: jnp.ndarray    # [U] grouped-buffer row for self units (valid where dest==me)
    # receiver side (per recv row)
    row_target: jnp.ndarray       # [G, c_pair] grouped-buffer row per recv row
    row_valid: jnp.ndarray        # [G, c_pair] bool
    # grouped buffer structure
    group_sizes: jnp.ndarray      # [n_groups] real rows per group
    group_offsets: jnp.ndarray    # [n_groups] block-aligned start row per group
    group_expert: jnp.ndarray     # [n_groups] expert id per group (-1 = inactive)
    fids: jnp.ndarray             # [K] foreign expert ids on this rank (-1 = none)
    # diagnostics
    send_drops: jnp.ndarray
    dest_drops: jnp.ndarray


class DispatchDiag(NamedTuple):
    send_drops: jnp.ndarray
    dest_drops: jnp.ndarray
    local_units: jnp.ndarray      # units processed on this rank (load)


def replica_slot_map(replica_ids: jnp.ndarray, padded_experts: int) -> jnp.ndarray:
    """replica_ids [..., R] int32 (-1 = empty slot) -> [..., Ep] expert->slot
    map (-1 = no replica). Traced-safe: one-hot max, no scatter, so the same
    jit entry serves every slot assignment. Highest slot wins a (degenerate)
    duplicate."""
    R = replica_ids.shape[-1]
    tgt = jnp.where(replica_ids >= 0, replica_ids, padded_experts)
    onehot = tgt[..., :, None] == jnp.arange(padded_experts, dtype=jnp.int32)
    slots = jnp.arange(R, dtype=jnp.int32)[:, None]
    return jnp.max(jnp.where(onehot, slots, -1), axis=-2)


def _exclusive_cumsum(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    c = jnp.cumsum(x, axis=axis)
    zero_shape = list(x.shape)
    zero_shape[axis] = 1
    zeros = jnp.zeros(zero_shape, x.dtype)
    return jnp.concatenate([zeros, jax.lax.slice_in_dim(c, 0, x.shape[axis] - 1,
                                                        axis=axis)], axis=axis)


def build_layout(S: jnp.ndarray, assign: jnp.ndarray, me: jnp.ndarray,
                 topo: EPTopology, *, c_pair: int, c_total: int,
                 num_foreign_slots: int, block_m: int,
                 num_replica_slots: int = 0,
                 replica_ids_me: jnp.ndarray | None = None) -> DispatchLayout:
    """Derive the full dispatch layout from schedule S and local assignment.

    S: [G, Ep, G] replicated; assign: [T_slice, k] local expert choices,
    values in [0, Ep] where the sentinel ``Ep`` marks padding units that must
    never be scheduled (they fall through as drops with zero payload);
    me: this rank's index on the EP axis; replica_ids_me: [R] traced expert
    ids occupying this rank's replica slots (-1 = empty).
    """
    G, Ep = topo.num_ranks, topo.padded_experts
    epr = topo.experts_per_rank
    K = num_foreign_slots
    R = num_replica_slots
    n_groups = epr + R + K
    unit_expert = assign.reshape(-1)                        # [U], token-major
    U = unit_expert.shape[0]
    is_pad_unit = unit_expert >= Ep

    # ---- sender side -------------------------------------------------
    # histogram/cumsum arrays carry an extra row for the padding sentinel
    counts_local = jnp.zeros((Ep + 1,), jnp.int32).at[unit_expert].add(1)
    # r: within-expert rank of each unit, in unit order (stable)
    sort_idx = jnp.argsort(unit_expert, stable=True)
    start_of_expert = _exclusive_cumsum(counts_local, 0)    # [Ep+1]
    r_sorted = jnp.arange(U, dtype=jnp.int32) - start_of_expert[unit_expert[sort_idx]]
    r = jnp.zeros((U,), jnp.int32).at[sort_idx].set(r_sorted)

    S_me = jnp.take(S, me, axis=0)                          # [Ep, G] my row
    S_me = jnp.concatenate([S_me, jnp.zeros((1, G), S_me.dtype)], axis=0)
    dcum = jnp.concatenate([jnp.zeros((Ep + 1, 1), jnp.int32),
                            jnp.cumsum(S_me, axis=1)], axis=1)  # [Ep+1, G+1]
    dcum_u = dcum[unit_expert]                              # [U, G+1]
    unit_dest = jnp.sum(r[:, None] >= dcum_u[:, 1:], axis=1).astype(jnp.int32)
    unit_dest = jnp.minimum(unit_dest, G - 1)               # unscheduled -> clamp (dropped below)
    scheduled = (r < dcum_u[:, G]) & ~is_pad_unit           # unit covered by S at all

    # row within the (me -> dest) pair chunk: by (e, r) within the chunk
    pair_e_off = _exclusive_cumsum(S_me, 0)                 # [Ep+1, G] rows of earlier experts
    unit_pair_pos = (pair_e_off[unit_expert, unit_dest]
                     + r - dcum[unit_expert, unit_dest])

    # ---- receiver-side group structure (all replicated-computable) ----
    recv_counts = jnp.take(S, me, axis=2)                   # [G_src, Ep]
    tok_e = recv_counts.sum(axis=0)                         # [Ep] units per expert on me
    lsl = jnp.asarray(local_slot_of(topo))                  # [G, Ep] static
    my_local_slot = jnp.take(lsl, me, axis=0)               # [Ep] (-1 if not local)
    if R and replica_ids_me is not None:
        rep_slot = replica_slot_map(replica_ids_me, Ep)     # [Ep] (-1 if none)
    else:
        rep_slot = jnp.full((Ep,), -1, jnp.int32)
    is_replica = (my_local_slot < 0) & (rep_slot >= 0)
    is_foreign_active = (tok_e > 0) & (my_local_slot < 0) & ~is_replica
    foreign_rank = jnp.cumsum(is_foreign_active.astype(jnp.int32)) - 1
    # fids[k] = k-th active foreign expert (by expert id)
    scatter_idx = jnp.where(is_foreign_active,
                            jnp.minimum(foreign_rank, K), K)
    fids = jnp.full((K + 1,), -1, jnp.int32).at[scatter_idx].set(
        jnp.arange(Ep, dtype=jnp.int32), mode="drop")[:K]
    # group of each expert on me: local slot j -> group j; replica slot r ->
    # epr + r; k-th foreign -> epr + R + k
    grp_of_e = jnp.where(
        my_local_slot >= 0, my_local_slot,
        jnp.where(is_replica, epr + rep_slot,
                  jnp.where(is_foreign_active & (foreign_rank < K),
                            epr + R + foreign_rank, n_groups)))  # n_groups = invalid
    group_expert = jnp.full((n_groups + 1,), -1, jnp.int32).at[
        jnp.minimum(grp_of_e, n_groups)].set(jnp.arange(Ep, dtype=jnp.int32),
                                             mode="drop")
    # only experts with tokens or local residence define groups
    slot_experts = jnp.take(jnp.asarray(topo.slot_map), me, axis=0)  # [epr]
    group_expert = group_expert.at[jnp.arange(epr)].set(slot_experts)
    group_expert = group_expert[:n_groups]
    if R and replica_ids_me is not None:
        group_expert = group_expert.at[epr + jnp.arange(R)].set(replica_ids_me)

    group_sizes = jnp.zeros((n_groups + 1,), jnp.int32).at[
        jnp.minimum(grp_of_e, n_groups)].add(tok_e, mode="drop")[:n_groups]
    padded = round_up_j(group_sizes, block_m)
    group_offsets = _exclusive_cumsum(padded, 0)            # block-aligned starts
    overflow_rows = jnp.minimum(
        jnp.maximum(group_offsets + padded - c_total, 0), group_sizes)

    # within-group offset of source g for expert e: earlier sources first
    wgo = _exclusive_cumsum(recv_counts, 0)                 # [G_src, Ep]

    # ---- receiver side: map each recv row (g, c) -> grouped row --------
    ecum = jnp.concatenate([jnp.zeros((G, 1), jnp.int32),
                            jnp.cumsum(recv_counts, axis=1)], axis=1)  # [G, Ep+1]
    c_idx = jnp.arange(c_pair, dtype=jnp.int32)
    # e_row[g, c]: which expert the c-th row of pair (g -> me) carries
    e_row = jax.vmap(lambda bounds: jnp.searchsorted(
        bounds, c_idx, side="right").astype(jnp.int32))(ecum[:, 1:])
    e_row = jnp.minimum(e_row, Ep - 1)
    r_rel = c_idx[None, :] - jnp.take_along_axis(ecum, e_row, axis=1)
    pair_total = ecum[:, Ep]
    row_valid = (c_idx[None, :] < pair_total[:, None]) \
        & (jnp.arange(G)[:, None] != me)                    # self handled directly
    grp_row = grp_of_e[e_row]                               # [G, c_pair]
    row_target = (jnp.take(group_offsets, jnp.minimum(grp_row, n_groups - 1))
                  + jnp.take_along_axis(wgo, e_row, axis=1)
                  + r_rel)
    row_valid = row_valid & (grp_row < n_groups)
    row_target = jnp.where(row_valid, row_target, c_total)  # oob -> dropped

    # ---- self units: grouped row computed sender-side ------------------
    ue_c = jnp.minimum(unit_expert, Ep - 1)                 # clamp pad sentinel
    grp_u = grp_of_e[ue_c]
    wgo_me = jnp.take(wgo, me, axis=0)                      # [Ep]
    unit_row_self = (jnp.take(group_offsets, jnp.minimum(grp_u, n_groups - 1))
                     + wgo_me[ue_c]
                     + (r - dcum[unit_expert, unit_dest]))
    unit_row_self = jnp.where((unit_dest == me) & scheduled & (grp_u < n_groups),
                              unit_row_self, c_total)

    send_valid = (unit_dest != me) & scheduled & (unit_pair_pos < c_pair)
    send_drops = jnp.sum((unit_dest != me) & scheduled
                         & (unit_pair_pos >= c_pair))
    # buffer-overflow drops + units scheduled here with no group to land in
    # (no local/replica slot and the foreign-slot budget exhausted)
    dest_drops = overflow_rows.sum() + jnp.sum(tok_e * (grp_of_e == n_groups))
    unit_pair_pos = jnp.where(send_valid, unit_pair_pos, c_pair)  # oob -> dropped

    return DispatchLayout(
        unit_dest=unit_dest, unit_pair_pos=unit_pair_pos,
        unit_row_self=unit_row_self,
        row_target=row_target, row_valid=row_valid,
        group_sizes=group_sizes, group_offsets=group_offsets,
        group_expert=group_expert, fids=fids,
        send_drops=send_drops.astype(jnp.int32),
        dest_drops=dest_drops.astype(jnp.int32),
    )


def round_up_j(x: jnp.ndarray, m: int) -> jnp.ndarray:
    return ((x + m - 1) // m) * m


def dispatch(x_units: jnp.ndarray, layout: DispatchLayout, *, axis_name: str,
             num_ranks: int, c_pair: int, c_total: int) -> jnp.ndarray:
    """Scatter local units to the grouped buffers of their destinations.

    x_units: [U, d] unit payloads (token embedding per (token, k) choice).
    Returns the grouped compute buffer [c_total, d] for *this* rank.
    """
    d = x_units.shape[-1]
    send = jnp.zeros((num_ranks, c_pair, d), x_units.dtype).at[
        layout.unit_dest, layout.unit_pair_pos].set(x_units, mode="drop")
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)
    grouped = jnp.zeros((c_total, d), x_units.dtype).at[
        layout.row_target.reshape(-1)].set(
        recv.reshape(num_ranks * c_pair, d)
        * layout.row_valid.reshape(-1, 1).astype(x_units.dtype), mode="drop")
    # self units go straight into the grouped buffer (no wire bytes)
    grouped = grouped.at[layout.unit_row_self].set(x_units, mode="drop")
    return grouped


def combine(out_grouped: jnp.ndarray, layout: DispatchLayout, *,
            axis_name: str, num_ranks: int, c_pair: int,
            gates: jnp.ndarray, top_k: int) -> jnp.ndarray:
    """Return processed rows to their source ranks and gate-combine (step 6)."""
    d = out_grouped.shape[-1]
    c_total = out_grouped.shape[0]
    padded_out = jnp.concatenate(
        [out_grouped, jnp.zeros((1, d), out_grouped.dtype)], axis=0)
    back = padded_out[jnp.minimum(layout.row_target, c_total)].reshape(
        num_ranks, c_pair, d)
    back = back * layout.row_valid[..., None].astype(back.dtype)
    ret = jax.lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0,
                             tiled=True)
    # per-unit outputs: remote units read ret[dest, pos]; self units read grouped
    pad_ret = jnp.concatenate(
        [ret, jnp.zeros((num_ranks, 1, d), ret.dtype)], axis=1)
    y_remote = pad_ret[layout.unit_dest, jnp.minimum(layout.unit_pair_pos, c_pair)]
    y_self = padded_out[jnp.minimum(layout.unit_row_self, c_total)]
    is_self = (layout.unit_row_self < c_total)[:, None].astype(y_self.dtype)
    y_units = y_self * is_self + y_remote * (1 - is_self)
    # gate-weighted combine over the k choices of each token
    U = y_units.shape[0]
    T = U // top_k
    y = (y_units.reshape(T, top_k, d)
         * gates.reshape(T, top_k, 1).astype(y_units.dtype)).sum(axis=1)
    return y
