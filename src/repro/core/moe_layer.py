"""The HarMoEny MoE block — paper Algorithm 1 as a shard_map island.

Data flow per EP rank (the paper's six steps):
  1. token routing          -> route_topk / route_skewed (router.py)
  2. metadata exchange      -> all_gather of the [Ep] count histogram (~kB)
  3. token scheduling       -> replicated deterministic schedule (scheduler.py)
  4. scatter tokens         -> static-capacity all_to_all (dispatch.py)
  5. expert processing      -> grouped FFN + foreign-weight fetch off the
                               critical path (grouped_ffn.py, prefetch.py)
  6. gather tokens          -> reverse all_to_all + gate combine (dispatch.py)

The island takes x replicated over the EP ('model') axis and sharded over the
batch axes; each EP rank owns a contiguous token slice (the paper's per-GPU
minibatch). Every (pod, data) row runs an independent protocol instance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, round_up
from repro.core import dispatch as D
from repro.core import prefetch
from repro.core import router as R
from repro.core import scheduler as SCH
from repro.core.grouped_ffn import grouped_ffn
from repro.core.qthreshold import q_threshold
from repro.core.topology import EPTopology, make_topology

from repro.core.compat import shard_map as _shard_map


@dataclass(frozen=True)
class MoEBlockSpec:
    """Static plumbing for one MoE block on a given mesh.

    ``tp_mode``: when the expert count is below the EP degree (mixtral's 8
    experts on a 16-wide axis), expert parallelism is the wrong decomposition
    — hosting ratios force weight duplication and the paper's scheduling
    regime (E >= G) does not hold. In that case the block switches to
    tensor-parallel MoE: every rank holds a d_ff-slice of EVERY expert, the
    compute is perfectly balanced by construction (skew-insensitive), and the
    only collective is the row-parallel output psum (vLLM's Mixtral strategy;
    DESIGN.md §Arch-applicability).
    """
    moe: MoEConfig
    d_model: int
    ep_axis: str
    batch_axes: Tuple[str, ...]
    ep_degree: int
    tokens_local: int        # B_local * S_local (per batch-group)
    block_m: int = 128
    cf_pair: float = 2.0
    act: str = "silu"        # expert activation; "swiglu" handled via w_gate
    use_pallas: bool = False
    interpret: bool = False
    fetch_chunk: int = 2048
    tp_mode: bool = False
    # sequence parallelism: the island consumes x already seq-sharded over
    # the EP axis (each rank's shard IS its token slice — no dynamic_slice in,
    # no all_gather out). Requires seq_len % ep_degree == 0; decode uses the
    # replicated path.
    seq_sharded: bool = False

    @property
    def topo(self) -> EPTopology:
        assert not self.tp_mode
        return make_topology(self.ep_degree, self.moe.num_experts,
                             placement=self.moe.placement)

    @property
    def t_pad(self) -> int:
        return round_up(max(self.tokens_local, self.ep_degree), self.ep_degree)

    @property
    def t_slice(self) -> int:
        return self.t_pad // self.ep_degree

    @property
    def units_per_rank(self) -> int:
        return self.t_slice * self.moe.num_experts_per_tok

    @property
    def c_pair(self) -> int:
        per_dest = -(-self.units_per_rank // self.ep_degree)  # ceil
        return max(int(self.cf_pair * per_dest), 8)

    @property
    def n_groups(self) -> int:
        # compute-buffer group order: local | replica | foreign
        return (self.topo.experts_per_rank + self.moe.num_replica_slots
                + self.moe.num_foreign_slots)

    @property
    def c_total(self) -> int:
        cap = int(self.moe.capacity_factor * self.units_per_rank)
        return round_up(max(cap, self.block_m), self.block_m) \
            + self.n_groups * self.block_m

    @property
    def q(self) -> int:
        if self.moe.q_tokens:
            return self.moe.q_tokens
        return q_threshold(ep_degree=self.ep_degree, dense_fetch=True)


def init_moe_params(key: jax.Array, spec: MoEBlockSpec,
                    dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Global (pjit-view) parameters.

    EP mode: expert rows are rank-major slot rows — row g*epr + j holds
    expert topo.slot_map[g, j] (duplicated when E < G). TP mode: plain
    [E, d, f] (sharded over d_ff by the sharding rules)."""
    d, f = spec.d_model, spec.moe.d_ff_expert
    if spec.tp_mode:
        n_rows, n_router = spec.moe.num_experts, spec.moe.num_experts
    else:
        topo = spec.topo
        n_rows = topo.num_ranks * topo.experts_per_rank
        n_router = topo.padded_experts
    k_r, k_i, k_g, k_o = jax.random.split(key, 4)
    scale_in = (2.0 / d) ** 0.5
    scale_out = (2.0 / f) ** 0.5
    params = {
        "router": (jax.random.normal(k_r, (d, n_router)) * 0.02
                   ).astype(jnp.float32),
        "w_in": (jax.random.normal(k_i, (n_rows, d, f)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k_o, (n_rows, f, d)) * scale_out).astype(dtype),
    }
    if spec.act == "silu":  # swiglu experts carry a gate matrix
        params["w_gate"] = (jax.random.normal(k_g, (n_rows, d, f))
                            * scale_in).astype(dtype)
    R = spec.moe.num_replica_slots
    if R and not spec.tp_mode:
        # replica slots start empty (all replica_ids = -1, never scheduled);
        # serve/rebalance.py swaps hot experts' weight rows in between windows
        rep_rows = spec.ep_degree * R
        params["w_rep_in"] = jnp.zeros((rep_rows, d, f), dtype)
        params["w_rep_out"] = jnp.zeros((rep_rows, f, d), dtype)
        if spec.act == "silu":
            params["w_rep_gate"] = jnp.zeros((rep_rows, d, f), dtype)
    return params


def _moe_forward_local(x_rep: jnp.ndarray, params: Dict[str, jnp.ndarray],
                       spec: MoEBlockSpec, n_valid: int,
                       skew_key: Optional[jax.Array],
                       valid_rep: Optional[jnp.ndarray] = None,
                       replica_ids: Optional[jnp.ndarray] = None,
                       residency_ids: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Per-rank body (inside shard_map). x_rep: [t_pad, d] replicated over EP.

    replica_ids: [G, R] replicated traced int32 — the expert id occupying each
    rank's replica slots (-1 = empty). Required (possibly all -1) whenever
    ``spec.moe.num_replica_slots > 0`` so buffer/weight shapes stay static.

    residency_ids: [G, W] replicated traced int32 — each rank's HBM-resident
    working set under tiered expert residency (serve/residency.py); experts
    statically placed on a rank but absent from its table are demoted to
    fetch-paying ``non_local`` destinations in the harmoeny schedule.
    """
    topo = spec.topo
    moe = spec.moe
    G, Ep = topo.num_ranks, topo.padded_experts
    k = moe.num_experts_per_tok
    R_slots = moe.num_replica_slots
    me = jax.lax.axis_index(spec.ep_axis)

    if spec.seq_sharded:
        x_slice = x_rep                     # already this rank's token slice
        t_slice = x_rep.shape[0]
    else:
        t_slice = x_rep.shape[0] // G
        x_slice = jax.lax.dynamic_slice_in_dim(x_rep, me * t_slice,
                                               t_slice, axis=0)

    # --- step 1: routing ------------------------------------------------
    if skew_key is not None and moe.router_skew > 0.0:
        key = jax.random.fold_in(skew_key, me)
        r_out = R.route_skewed(key, t_slice, top_k=k,
                               num_experts=moe.num_experts,
                               padded_experts=Ep, alpha=moe.router_skew,
                               n_hot=moe.router_skew_experts)
    else:
        r_out = R.route_topk(x_slice, params["router"], top_k=k,
                             num_real_experts=moe.num_experts)
    # mark padding tokens with the sentinel expert id Ep (never scheduled);
    # valid_rep additionally masks caller-declared dead tokens (inactive
    # decode slots, prompt-chunk padding) out of routing and capacity
    tok_idx = me * t_slice + jnp.arange(t_slice)
    valid_tok = tok_idx < n_valid
    if valid_rep is not None:
        v_slice = valid_rep if spec.seq_sharded else \
            jax.lax.dynamic_slice_in_dim(valid_rep, me * t_slice, t_slice,
                                         axis=0)
        valid_tok = valid_tok & v_slice
    assign = jnp.where(valid_tok[:, None], r_out.assign, Ep)
    counts = jnp.zeros((Ep,), jnp.int32).at[assign.reshape(-1)].add(
        1, mode="drop")

    # --- step 2: metadata exchange (~G*Ep*4 bytes on the wire) -----------
    m_all = jax.lax.all_gather(counts, spec.ep_axis, axis=0)        # [G, Ep]

    # --- step 3: replicated deterministic scheduling ----------------------
    extra_local = None
    rep_ids_me = None
    if R_slots and replica_ids is not None:
        # ranks holding a replica of e count as local destinations for e
        extra_local = D.replica_slot_map(replica_ids, Ep) >= 0  # [G, Ep]
        rep_ids_me = jnp.take(replica_ids, me, axis=0)          # [R]
    non_local = None
    if residency_ids is not None:
        # tiered residency: statically-placed experts swapped out of HBM
        # stop counting as free destinations for the rebalancer
        non_local = prefetch.residency_non_local(residency_ids, topo)
    S, sdiag = SCH.schedule(m_all, topo, policy=moe.policy, q=spec.q,
                            c_pair=spec.c_pair,
                            num_foreign_slots=moe.num_foreign_slots,
                            extra_local=extra_local, non_local=non_local)

    # --- step 4: scatter ---------------------------------------------------
    layout = D.build_layout(S, assign, me, topo, c_pair=spec.c_pair,
                            c_total=spec.c_total,
                            num_foreign_slots=moe.num_foreign_slots,
                            block_m=spec.block_m,
                            num_replica_slots=R_slots,
                            replica_ids_me=rep_ids_me)
    x_units = jnp.repeat(x_slice, k, axis=0)                # token-major, k-minor
    grouped = D.dispatch(x_units, layout, axis_name=spec.ep_axis,
                         num_ranks=G, c_pair=spec.c_pair,
                         c_total=spec.c_total)

    # --- step 5: expert processing + async weight fetch --------------------
    w_in, w_out = params["w_in"], params["w_out"]           # local shards [epr,...]
    w_gate = params.get("w_gate")
    # replica-slot weight rows for this rank ([R, ...] shards, zeros if empty)
    w_rep = {name: params.get(name) for name in
             ("w_rep_in", "w_rep_out", "w_rep_gate")}

    def with_replicas(w, rep_name):
        wr = w_rep[rep_name]
        return w if wr is None else jnp.concatenate(
            [w, wr.astype(w.dtype)], axis=0)
    if moe.policy == "even_split":
        # full replication (paper's Even-Split): gather all experts; the map
        # covers every group row — local, replica, and foreign alike
        def per_group(w):
            w_all = prefetch.gather_all_experts(w, axis_name=spec.ep_axis)
            rows = _expert_row_map(topo)
            ge = jnp.minimum(jnp.maximum(layout.group_expert, 0), Ep - 1)
            return w_all[jnp.asarray(rows)[ge]]
        w_in_full, w_out_full = per_group(w_in), per_group(w_out)
        w_gate_full = per_group(w_gate) if w_gate is not None else None
    elif moe.num_foreign_slots > 0:
        fids_all = prefetch.all_foreign_ids(S, topo, moe.num_foreign_slots,
                                            replica_ids=replica_ids
                                            if R_slots else None)

        def fetch(w, rep_name):
            wf = prefetch.fetch_foreign_weights(
                w, fids_all, me, topo, axis_name=spec.ep_axis,
                fetch_chunk=spec.fetch_chunk)
            return jnp.concatenate([with_replicas(w, rep_name),
                                    wf.astype(w.dtype)], axis=0)
        w_in_full = fetch(w_in, "w_rep_in")
        w_out_full = fetch(w_out, "w_rep_out")
        w_gate_full = fetch(w_gate, "w_rep_gate") \
            if w_gate is not None else None
    else:
        w_in_full = with_replicas(w_in, "w_rep_in")
        w_out_full = with_replicas(w_out, "w_rep_out")
        w_gate_full = (with_replicas(w_gate, "w_rep_gate")
                       if w_gate is not None else None)

    sizes_padded = D.round_up_j(layout.group_sizes, spec.block_m)
    out_grouped = grouped_ffn(grouped, w_in_full, w_out_full, sizes_padded,
                              w_gate=w_gate_full, act=spec.act,
                              use_pallas=spec.use_pallas,
                              interpret=spec.interpret,
                              block_m=spec.block_m)

    # --- step 6: gather + combine ------------------------------------------
    y_slice = D.combine(out_grouped, layout, axis_name=spec.ep_axis,
                        num_ranks=G, c_pair=spec.c_pair,
                        gates=r_out.gates, top_k=k)
    y_rep = (y_slice if spec.seq_sharded
             else jax.lax.all_gather(y_slice, spec.ep_axis, axis=0, tiled=True))

    t_g = S.sum(axis=(0, 1)).astype(jnp.float32)
    # drops are per-rank quantities; sum them so the reported diagnostic is
    # the honest global count (out_specs otherwise surface one rank's shard)
    send_drops = jax.lax.psum(layout.send_drops, spec.ep_axis)
    dest_drops = jax.lax.psum(layout.dest_drops, spec.ep_axis)
    diag = {
        "aux_loss": r_out.aux_loss[None],
        "send_drops": send_drops[None].astype(jnp.float32),
        "dest_drops": dest_drops[None].astype(jnp.float32),
        "sched_iters": sdiag.iters[None].astype(jnp.float32),
        "moved_units": sdiag.moved[None].astype(jnp.float32),
        "max_load_before": sdiag.max_load_before[None].astype(jnp.float32),
        "max_load_after": sdiag.max_load_after[None].astype(jnp.float32),
        "mean_load": t_g.mean()[None],
        # vector diagnostics (paper §5 measurements): scheduled units per
        # rank and routed units per expert for this step
        "rank_load": t_g[None, :],                              # [1, G]
        "expert_load": m_all.sum(axis=0).astype(jnp.float32)[None, :],  # [1, Ep]
    }
    return y_rep, diag


# diagnostic keys emitted by every MoE block variant; scalars are [1]-shaped
# inside the shard_map body, vectors are [1, N] (N = ranks / experts)
SCALAR_DIAGS = ("aux_loss", "send_drops", "dest_drops", "sched_iters",
                "moved_units", "max_load_before", "max_load_after",
                "mean_load")
VECTOR_DIAGS = ("rank_load", "expert_load")


def _diag_out_specs(batch_spec):
    P = jax.sharding.PartitionSpec
    specs = {key: P(batch_spec) for key in SCALAR_DIAGS}
    specs.update({key: P(batch_spec, None) for key in VECTOR_DIAGS})
    return specs


def _expert_row_map(topo: EPTopology):
    """expert id -> its first global slot row (static)."""
    import numpy as np
    rows = np.zeros((topo.padded_experts,), np.int32)
    for g in range(topo.num_ranks):
        for j in range(topo.experts_per_rank):
            e = topo.slot_map[g, j]
            rows[e] = g * topo.experts_per_rank + j
    return rows


def tp_moe_block(x: jnp.ndarray, params: Dict[str, jnp.ndarray], *,
                 spec: MoEBlockSpec, mesh: jax.sharding.Mesh,
                 skew_key: Optional[jax.Array] = None
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Tensor-parallel MoE (E < EP degree). Every rank holds a d_ff slice of
    every expert: local sort-by-expert + exact ragged matmuls + output psum.
    Perfectly balanced by construction; zero drops (no capacity bounds)."""
    P = jax.sharding.PartitionSpec
    B, S_len, d = x.shape
    E = spec.moe.num_experts
    k = spec.moe.num_experts_per_tok
    batch_spec = spec.batch_axes if spec.batch_axes else None

    bm = spec.block_m

    def body(xb, p_router, w_in, w_out, w_gate, key):
        B_loc = xb.shape[0]
        T = B_loc * S_len
        flat = xb.reshape(T, d)
        if key is not None and spec.moe.router_skew > 0.0:
            r = R.route_skewed(key, T, top_k=k, num_experts=E,
                               padded_experts=E, alpha=spec.moe.router_skew,
                               n_hot=spec.moe.router_skew_experts)
        else:
            r = R.route_topk(flat, p_router, top_k=k, num_real_experts=E)
        ue = r.assign.reshape(-1)
        U = ue.shape[0]
        # block-aligned grouped buffer (exact: capacity covers all units)
        sizes = jnp.zeros((E,), jnp.int32).at[ue].add(1)
        padded = D.round_up_j(sizes, bm)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)[:-1]])
        order = jnp.argsort(ue, stable=True)
        start = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1]])
        r_sorted = jnp.arange(U, dtype=jnp.int32) - start[ue[order]]
        rank_in_e = jnp.zeros((U,), jnp.int32).at[order].set(r_sorted)
        row = offsets[ue] + rank_in_e
        m_pad = round_up(U, bm) + E * bm
        x_units = jnp.repeat(flat, k, axis=0)
        x_buf = jnp.zeros((m_pad, d), flat.dtype).at[row].set(x_units)
        y_buf = grouped_ffn(x_buf, w_in, w_out, padded, w_gate=w_gate,
                            act=spec.act, use_pallas=spec.use_pallas,
                            interpret=spec.interpret, block_m=bm)
        y_buf = jax.lax.psum(y_buf, spec.ep_axis)          # row-parallel
        y_units = y_buf[row]
        y = (y_units.reshape(T, k, d)
             * r.gates.reshape(T, k, 1).astype(y_units.dtype)).sum(axis=1)
        zero = jnp.zeros((1,), jnp.float32)
        diag = {"aux_loss": r.aux_loss[None], "send_drops": zero,
                "dest_drops": zero, "sched_iters": zero, "moved_units": zero,
                "max_load_before": zero, "max_load_after": zero,
                "mean_load": zero,
                # TP-MoE is compute-balanced by construction: every rank
                # holds a d_ff slice of every unit's expert
                "rank_load": jnp.full((1, spec.ep_degree),
                                      U / spec.ep_degree, jnp.float32),
                "expert_load": sizes.astype(jnp.float32)[None, :]}
        return y.reshape(B_loc, S_len, d).astype(xb.dtype), diag

    in_specs = (
        P(batch_spec, None, None),
        P(None, None),
        P(None, None, spec.ep_axis),                # w_in: f-sliced
        P(None, spec.ep_axis, None),                # w_out: f-sliced
        (P(None, None, spec.ep_axis) if "w_gate" in params else None),
        (P() if skew_key is not None else None),
    )
    out_specs = (P(batch_spec, None, None), _diag_out_specs(batch_spec))
    fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(x, params["router"], params["w_in"], params["w_out"],
              params.get("w_gate"), skew_key)


def moe_block(x: jnp.ndarray, params: Dict[str, jnp.ndarray], *,
              spec: MoEBlockSpec, mesh: jax.sharding.Mesh,
              skew_key: Optional[jax.Array] = None,
              valid_mask: Optional[jnp.ndarray] = None,
              replica_ids: Optional[jnp.ndarray] = None,
              residency_ids: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Global-view MoE block. x: [B, S, d] -> [B, S, d], diagnostics.

    Batch is sharded over ``spec.batch_axes``; experts over ``spec.ep_axis``
    (or d_ff over ``spec.ep_axis`` in TP mode — see MoEBlockSpec).
    ``valid_mask`` [B, S] (bool) excludes dead tokens — inactive serving
    slots, prompt-chunk padding — from routing, capacity, and the schedule
    diagnostics; their outputs are still produced (garbage) and must be
    discarded by the caller.
    ``replica_ids`` [G, R] int32 (traced; -1 = empty) names the expert whose
    weights currently occupy each rank's replica slots; defaults to all
    empty when ``spec.moe.num_replica_slots > 0``.
    ``residency_ids`` [G, W] int32 (traced; -1 = pad) names each rank's
    HBM-resident working set under tiered expert residency; None means
    everything is resident (no demotion).
    """
    if spec.tp_mode:
        # TP-MoE is capacity-free and compute-balanced; dead tokens cannot
        # drop real ones, so the mask (and replication) is unnecessary there.
        return tp_moe_block(x, params, spec=spec, mesh=mesh,
                            skew_key=skew_key)
    P = jax.sharding.PartitionSpec
    B, S_len, d = x.shape
    batch_spec = spec.batch_axes if spec.batch_axes else None

    R_slots = spec.moe.num_replica_slots
    if R_slots:
        assert "w_rep_in" in params, \
            "num_replica_slots > 0 requires w_rep_* params (init_moe_params)"
        if replica_ids is None:
            replica_ids = jnp.full((spec.ep_degree, R_slots), -1, jnp.int32)
    else:
        replica_ids = None

    def body(xb, p_router, p_in, p_out, p_gate, p_reps, rep_ids, res_ids,
             key, vmask):
        B_loc, S_loc = xb.shape[0], xb.shape[1]
        flat = xb.reshape(B_loc * S_loc, d)
        prm = {"router": p_router, "w_in": p_in, "w_out": p_out}
        if p_gate is not None:
            prm["w_gate"] = p_gate
        if p_reps is not None:
            prm.update(p_reps)
        if spec.seq_sharded:
            # xb (and vmask) are already this rank's token slice
            y, diag = _moe_forward_local(
                flat, prm, spec, flat.shape[0] * spec.ep_degree, key,
                valid_rep=None if vmask is None else vmask.reshape(-1),
                replica_ids=rep_ids, residency_ids=res_ids)
            y = y.reshape(B_loc, S_loc, d)
        else:
            n_valid = flat.shape[0]
            t_pad = round_up(max(n_valid, spec.ep_degree), spec.ep_degree)
            x_rep = jnp.pad(flat, ((0, t_pad - n_valid), (0, 0)))
            v_rep = None
            if vmask is not None:
                v_rep = jnp.pad(vmask.reshape(-1),
                                (0, t_pad - n_valid))   # pads are invalid
            y, diag = _moe_forward_local(x_rep, prm, spec, n_valid, key,
                                         valid_rep=v_rep,
                                         replica_ids=rep_ids,
                                         residency_ids=res_ids)
            y = y[:n_valid].reshape(B_loc, S_loc, d)
        return y, diag

    rep_params = None
    rep_param_specs = None
    if R_slots:
        rep_params = {name: params[name] for name in
                      ("w_rep_in", "w_rep_out", "w_rep_gate")
                      if name in params}
        rep_param_specs = {name: P(spec.ep_axis, None, None)
                           for name in rep_params}
    x_seq_spec = spec.ep_axis if spec.seq_sharded else None
    in_specs = (
        P(batch_spec, x_seq_spec, None),           # x: batch (+seq) sharded
        P(None, None),                             # router replicated
        P(spec.ep_axis, None, None),               # expert rows over EP axis
        P(spec.ep_axis, None, None),
        (P(spec.ep_axis, None, None) if "w_gate" in params else None),
        rep_param_specs,                           # replica rows over EP axis
        (P(None, None) if replica_ids is not None else None),
        (P(None, None) if residency_ids is not None else None),
        (P() if skew_key is not None else None),
        (P(batch_spec, x_seq_spec) if valid_mask is not None else None),
    )
    out_specs = (P(batch_spec, x_seq_spec, None), _diag_out_specs(batch_spec))
    fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return fn(x, params["router"], params["w_in"], params["w_out"],
              params.get("w_gate"), rep_params, replica_ids, residency_ids,
              skew_key, valid_mask)
