"""Token-transfer threshold q (paper §4.4, Eq. 4), adapted to TPU v5e.

Paper (GPU):   q > phi * d_type / (2 * beta),  beta = PCIe bandwidth.
TPU adaptation: the fetch source is peer HBM over ICI and the fetch primitive
is a dense all_to_all whose ring cost scales the effective bandwidth by ~1/G
(DESIGN.md §2), so:

    q > phi * d_type / (2 * beta_ici / G_penalty)

with G_penalty = G for the dense a2a fetch (zeros ride the wire) and 1 for a
hypothetical sparse fetch. The estimator exposes both so benchmarks can show
the trade-off.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e per-chip constants (assignment-provided)."""
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # B/s
    ici_bw: float = 50e9             # B/s per link
    dtype_bytes: int = 2             # bf16


V5E = HardwareSpec()


def q_threshold(hw: HardwareSpec = V5E, *, ep_degree: int = 1,
                dense_fetch: bool = True) -> int:
    """Eq. 4 with the ICI substitution. Returns a per-chunk token count."""
    penalty = ep_degree if dense_fetch else 1
    beta_eff = hw.ici_bw / max(penalty, 1)
    q = hw.peak_flops * hw.dtype_bytes / (2.0 * beta_eff)
    return int(q) + 1


def expert_fetch_seconds(expert_bytes: float, hw: HardwareSpec = V5E, *,
                         ep_degree: int = 1, dense_fetch: bool = True) -> float:
    penalty = ep_degree if dense_fetch else 1
    return expert_bytes * penalty / hw.ici_bw


def expert_compute_seconds(tokens: float, d_model: int, d_ff: int,
                           n_matrices: int, hw: HardwareSpec = V5E) -> float:
    flops = 2.0 * tokens * d_model * d_ff * n_matrices
    return flops / hw.peak_flops
