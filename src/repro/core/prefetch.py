"""Asynchronous expert fetching, adapted to TPU (paper §4.3).

On the paper's hardware, experts are paged from *host* memory over PCIe into
a GPU-side cache, overwriting finished experts. At pod scale every expert
already lives in some peer's HBM, so the fetch source becomes peer HBM over
ICI (strictly faster than host DRAM) and the fetch primitive is a collective:

Every rank can compute every rank's foreign-expert needs from the replicated
schedule (`FIDS[G, K]`), so each source fills, for each destination, the K
expert-weight slots it hosts, and a single all_to_all delivers them; the
receiver sums over sources (exactly one source is non-zero per slot, or
``hosts_per_expert`` sources each contributing 1/hosts share).

XLA's latency-hiding scheduler overlaps this all_to_all with the attention /
shared-expert compute that precedes the grouped matmul — the analogue of the
paper's dedicated CUDA stream. The f-dimension is chunked (`fetch_chunk`) so
the transient buffer stays bounded for large experts (DESIGN.md §2).

Tiered residency fetch source (serve/residency.py): when expert weights
exceed HBM, a second, *slower* tier reappears — the paper's original
host-DRAM-over-PCIe source. The serve engine keeps a ``[G, W]`` residency
table (resident expert ids per rank, analogous to ``replica_ids``) that
rides into the jitted decode step as a traced argument;
:func:`residency_non_local` turns it into the scheduler's ``non_local``
demotion mask (statically-placed experts currently swapped out of HBM),
and :func:`stage_expert_rows` is the jitted host→HBM staging scatter the
engine dispatches ahead of the step so the copy double-buffers against
compute. Both are pure value functions of static-shape arrays: residency
swaps never change a traced shape, so the decode jit entry count stays 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import replica_slot_map
from repro.core.topology import EPTopology, local_slot_of


def all_foreign_ids(S: jnp.ndarray, topo: EPTopology,
                    num_foreign_slots: int,
                    replica_ids: jnp.ndarray | None = None) -> jnp.ndarray:
    """FIDS [G, K]: the k-th foreign expert of each destination (-1 = none).

    Replicated-computable: pure function of the replicated schedule S (and
    the replicated replica-slot assignment, when hot-expert replication is
    on: experts already weight-resident in a destination's replica slots
    never consume a foreign slot there).
    """
    G, Ep = topo.num_ranks, topo.padded_experts
    K = num_foreign_slots
    tok_e = S.sum(axis=0)                                    # [Ep, G_dst]
    lsl = jnp.asarray(local_slot_of(topo))                   # [G, Ep]
    active = (tok_e.T > 0) & (lsl < 0)                       # [G, Ep]
    if replica_ids is not None:
        active = active & (replica_slot_map(replica_ids, Ep) < 0)
    f_rank = jnp.cumsum(active.astype(jnp.int32), axis=1) - 1
    scatter = jnp.where(active, jnp.minimum(f_rank, K), K)   # [G, Ep]
    fids = jnp.full((G, K + 1), -1, jnp.int32)
    fids = fids.at[jnp.arange(G)[:, None], scatter].set(
        jnp.broadcast_to(jnp.arange(Ep, dtype=jnp.int32), (G, Ep)), mode="drop")
    return fids[:, :K]


def fetch_foreign_weights(w_local: jnp.ndarray, fids_all: jnp.ndarray,
                          me: jnp.ndarray, topo: EPTopology, *,
                          axis_name: str, fetch_chunk: int = 0) -> jnp.ndarray:
    """w_local [epr, ...] (this rank's expert shard) -> [K, ...] foreign weights.

    fids_all: FIDS [G, K] replicated. Works leaf-wise; call under tree_map for
    multi-matrix experts. ``fetch_chunk`` > 0 chunks the last dimension to
    bound the all_to_all transient for large experts.
    """
    G = topo.num_ranks
    K = fids_all.shape[1]
    slot_experts = jnp.take(jnp.asarray(topo.slot_map), me, axis=0)  # [epr]
    # mask[dst, k, j] = 1 iff my local slot j hosts dst's k-th foreign expert
    mask = (fids_all[:, :, None] == slot_experts[None, None, :])
    mask = mask.astype(w_local.dtype) / topo.hosts_per_expert

    def one_chunk(w):
        # outbox[dst, k, ...] = sum_j mask * w_local[j]
        out = jnp.einsum("dkj,j...->dk...", mask, w)
        ret = jax.lax.all_to_all(out, axis_name, split_axis=0, concat_axis=0,
                                 tiled=True)                 # [G_src, K, ...]
        return ret.sum(axis=0)                               # [K, ...]

    if fetch_chunk and w_local.shape[-1] > fetch_chunk:
        F = w_local.shape[-1]
        n = (F + fetch_chunk - 1) // fetch_chunk
        Fp = n * fetch_chunk
        w_pad = jnp.pad(w_local, [(0, 0)] * (w_local.ndim - 1) + [(0, Fp - F)])
        chunks = jnp.moveaxis(
            w_pad.reshape(w_pad.shape[:-1] + (n, fetch_chunk)), -2, 0)
        fetched = jax.lax.map(one_chunk, chunks)             # [n, K, ..., chunk]
        fetched = jnp.moveaxis(fetched, 0, -2).reshape(
            (K,) + w_local.shape[1:-1] + (Fp,))
        return fetched[..., :F]
    return one_chunk(w_local)


def residency_non_local(residency_ids: jnp.ndarray,
                        topo: EPTopology) -> jnp.ndarray:
    """Residency table [G, W] -> scheduler ``non_local`` mask [G, Ep].

    True where an expert is statically placed on rank g but *not* in g's
    current HBM working set (-1 table pads never match a real expert).
    Traced-safe: reuses the replica-slot one-hot map, so the mask is a
    pure value function of the table and swaps never recompile.
    """
    resident = replica_slot_map(residency_ids, topo.padded_experts) >= 0
    static_local = jnp.asarray(local_slot_of(topo) >= 0)
    return static_local & ~resident


def stage_expert_rows(w: jnp.ndarray, rows: jnp.ndarray,
                      vals: jnp.ndarray) -> jnp.ndarray:
    """Scatter staged expert rows into a weight leaf (host→HBM emulation).

    ``w``: [..., rows, d, f] weight leaf (row axis third from last, same
    convention as the replica-swap gather). ``rows`` [n] stacked row
    indices, ``vals`` the staged values in ``w``'s layout with the row
    axis sized n. Duplicate row indices are allowed (padded stage lists
    repeat a row) because duplicates carry identical values.
    """
    axis = w.ndim - 3
    wt = jnp.moveaxis(w, axis, 0)
    vt = jnp.moveaxis(vals.astype(w.dtype), axis, 0)
    return jnp.moveaxis(wt.at[rows].set(vt), 0, axis)


def gather_all_experts(w_local: jnp.ndarray, *, axis_name: str) -> jnp.ndarray:
    """Even-Split policy support: replicate the full expert set on every rank
    (paper §5.3.2 — deliberately expensive; used by benchmarks only)."""
    return jax.lax.all_gather(w_local, axis_name, axis=0, tiled=True)
