"""HarMoEny token scheduling (paper Alg. 2) + baseline policies.

The schedule is the paper's ``S[g_from, e, g_to]`` int32 tensor: number of
routable units (token, expert-choice) sent from source EP rank ``g_from`` for
expert ``e`` to destination EP rank ``g_to``.

All policies are *replicated deterministic* computations: every rank runs the
same function on the same all-gathered metadata and obtains the same schedule
(paper §4.1 step 3 — no synchronization beyond the metadata exchange).

TPU/static-shape extensions over the paper (DESIGN.md §2):
  * off-diagonal pair capacity ``c_pair`` (the all_to_all buffer is static);
    the self-pair (g -> g) bypasses the network and is exempt;
  * at most ``num_foreign_slots`` distinct non-resident experts per
    destination (static foreign weight buffers);
  * bounded iteration count (`max_iters`) for the while_loop.

Invariant (tested by hypothesis): every policy conserves
``S.sum(axis=2) == counts`` — tokens are never created or destroyed by
scheduling; only the destination changes. Drops can only happen later, at
dispatch, when a static buffer overflows, and are counted there.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import EPTopology, local_slot_of

_INT_MAX = jnp.iinfo(jnp.int32).max


class ScheduleDiag(NamedTuple):
    iters: jnp.ndarray          # rebalance iterations executed
    moved: jnp.ndarray          # total units moved
    max_load_before: jnp.ndarray
    max_load_after: jnp.ndarray


def initial_assign(counts: jnp.ndarray, topo: EPTopology,
                   extra_local: jnp.ndarray | None = None) -> jnp.ndarray:
    """Paper Alg.1 line 11: S_initial — route every unit to its expert's host.

    counts: [G, Ep] int32. Returns S: [G, Ep, G] int32. For replicated
    experts (E < G) the load is split evenly across the host replicas
    (remainder to the first hosts).

    ``extra_local`` [G, Ep] bool marks replica-slot residencies
    (serve/rebalance.py): a source that already holds expert ``e``'s
    weights in a replica slot keeps its own units for ``e`` at home —
    the paper's replication payoff, skipping dispatch (and hence a2a
    payload + fetch) for the hot expert's local traffic entirely.
    """
    G, Ep = topo.num_ranks, topo.padded_experts
    r = topo.hosts_per_expert
    if extra_local is not None:
        keep = counts * extra_local.astype(counts.dtype)         # [G, Ep]
        counts = counts - keep
    S = jnp.zeros((G, Ep, G), jnp.int32)
    base = counts // r
    rem = counts % r
    for i in range(r):
        onehot = np.zeros((Ep, G), np.int32)
        onehot[np.arange(Ep), topo.host_of[:, i]] = 1
        share = base + (rem > i).astype(jnp.int32)
        S = S + share[:, :, None] * jnp.asarray(onehot)[None, :, :]
    if extra_local is not None:
        S = S + keep[:, :, None] * jnp.asarray(
            np.eye(G, dtype=np.int32))[:, None, :]
    return S


def even_split(counts: jnp.ndarray, topo: EPTopology) -> jnp.ndarray:
    """Paper §5.3.2 Even-Split policy: each expert's units split over all G."""
    G = topo.num_ranks
    base = counts // G
    rem = counts % G
    h = jnp.arange(G, dtype=jnp.int32)
    return base[:, :, None] + (h[None, None, :] < rem[:, :, None]).astype(jnp.int32)


class _LoopState(NamedTuple):
    S: jnp.ndarray              # [G, Ep, G]
    foreign: jnp.ndarray        # [G(dest), Ep] bool — active non-resident experts
    it: jnp.ndarray
    moved: jnp.ndarray
    done: jnp.ndarray


def rebalance(S_initial: jnp.ndarray, topo: EPTopology, *, q: int,
              c_pair: int, num_foreign_slots: int,
              max_iters: int = 128,
              extra_local: jnp.ndarray | None = None,
              non_local: jnp.ndarray | None = None
              ) -> tuple[jnp.ndarray, ScheduleDiag]:
    """Paper Alg. 2 (greedy token rebalancing) as a lax.while_loop.

    Two imbalance criteria, repaired by the same greedy move
    (g_from, e_max, g_hot) -> (g_from, e_max, g_min):
      A. an off-diagonal pair exceeds ``c_pair`` (static-buffer criterion;
         takes priority and ignores the q-threshold since the alternative is
         dropping tokens);
      B. a destination exceeds the average load t_avg (the paper's criterion,
         guarded by the q-threshold, Alg.2 lines 6-17).

    ``extra_local`` [G, Ep] bool (may be traced) marks additional
    weight-resident (expert, rank) pairs — replica slots filled by the
    serving-time rebalancer — that count as local destinations: schedulable
    at zero foreign-slot cost, exactly like the static placement.

    ``non_local`` [G, Ep] bool (may be traced) is the inverse demotion:
    statically-placed experts whose weights are *not currently
    HBM-resident* on their host (tiered residency, serve/residency.py).
    A demoted pair is treated like any other foreign destination — moving
    work there consumes a foreign slot and no longer rides free — so the
    rebalancer steers load toward ranks whose working set already holds
    the expert. Demotion applies after the ``extra_local`` promotion.
    """
    G, Ep = topo.num_ranks, topo.padded_experts
    is_local = jnp.asarray(local_slot_of(topo) >= 0)            # [G, Ep]
    if extra_local is not None:
        is_local = is_local | extra_local
    if non_local is not None:
        is_local = is_local & ~non_local
    offdiag = 1 - jnp.eye(G, dtype=jnp.int32)
    q = jnp.int32(q)

    total = S_initial.sum()
    t_avg = total // G                                           # Alg.2 line 4

    def t_g_of(S):
        return S.sum(axis=(0, 1))                                # line 5

    def cond(st: _LoopState):
        t_g = t_g_of(st.S)
        pair_over = (st.S.sum(axis=1) * offdiag) > c_pair
        return (~st.done) & (st.it < max_iters) & (
            jnp.any(t_g > t_avg) | jnp.any(pair_over))           # line 6

    def body(st: _LoopState) -> _LoopState:
        S, foreign = st.S, st.foreign
        t_g = t_g_of(S)
        pair = S.sum(axis=1)                                     # [G_src, G_dst]
        over_pair = pair * offdiag - c_pair
        has_pair_over = jnp.any(over_pair > 0)

        # --- pick (g_from, g_hot): the chunk we take tokens away from ---
        flatA = jnp.argmax(over_pair)
        gA_from, gA_hot = flatA // G, flatA % G
        gB_hot = jnp.argmax(t_g)                                 # line 7
        gB_from = jnp.argmax(pair[:, gB_hot])                    # line 8
        g_hot = jnp.where(has_pair_over, gA_hot, gB_hot)
        g_from = jnp.where(has_pair_over, gA_from, gB_from)

        col = jnp.take(jnp.take(S, g_from, axis=0), g_hot, axis=1)  # [Ep]
        e_max = jnp.argmax(col)                                  # line 9
        t_move = col[e_max]                                      # line 11

        # q-threshold (line 12) only guards the load criterion; pair overflow
        # must be repaired regardless (or the dispatch buffer drops tokens).
        stop_q = (~has_pair_over) & (t_move < q)

        # --- pick g_min among *feasible* destinations ---
        e_local = is_local[:, e_max]                             # [G]
        e_foreign_active = foreign[:, e_max]
        n_foreign = foreign.sum(axis=1)
        slot_ok = e_local | e_foreign_active | (n_foreign < num_foreign_slots)
        # pair capacity at the candidate destination (self-pair exempt)
        pair_from = pair[g_from]                                 # [G]
        pair_slack = jnp.where(jnp.arange(G) == g_from,
                               _INT_MAX, c_pair - pair_from)
        allowed = slot_ok & (pair_slack > 0)
        allowed = allowed.at[g_hot].set(False)
        g_min = jnp.argmin(jnp.where(allowed, t_g, _INT_MAX))    # line 15
        none_allowed = ~jnp.any(allowed)

        # destination headroom (line 16/19); pair repair may exceed t_avg by q
        headroom = t_avg - t_g[g_min] + jnp.where(has_pair_over, q, 0)
        t_s = jnp.minimum(t_move, jnp.minimum(headroom, pair_slack[g_min]))
        # for pair repair we only need to shed the overflow
        t_s = jnp.where(has_pair_over,
                        jnp.minimum(t_s, jnp.maximum(over_pair[g_from, g_hot], 0)),
                        t_s)

        stop_cap = (~has_pair_over) & (t_g[g_min] + q > t_avg)   # line 16
        done = stop_q | none_allowed | (g_min == g_hot) | (t_s <= 0) | stop_cap

        S_new = S.at[g_from, e_max, g_hot].add(-t_s) \
                 .at[g_from, e_max, g_min].add(t_s)              # lines 20-23
        f_new = foreign.at[g_min, e_max].set(
            foreign[g_min, e_max] | ~is_local[g_min, e_max])
        return _LoopState(
            S=jnp.where(done, S, S_new),
            foreign=jnp.where(done, foreign, f_new),
            it=st.it + 1,
            moved=st.moved + jnp.where(done, 0, t_s),
            done=done,
        )

    init = _LoopState(S_initial, jnp.zeros((G, Ep), bool),
                      jnp.int32(0), jnp.int32(0), jnp.bool_(False))
    final = jax.lax.while_loop(cond, body, init)
    diag = ScheduleDiag(final.it, final.moved,
                        t_g_of(S_initial).max(), t_g_of(final.S).max())
    return final.S, diag


def schedule(counts: jnp.ndarray, topo: EPTopology, *, policy: str, q: int,
             c_pair: int, num_foreign_slots: int,
             max_iters: int = 128,
             extra_local: jnp.ndarray | None = None,
             non_local: jnp.ndarray | None = None
             ) -> tuple[jnp.ndarray, ScheduleDiag]:
    """counts [G, Ep] -> (S [G, Ep, G], diagnostics) under ``policy``.

    policies: harmoeny | round_robin | even_split | static_opt.
    ``static_opt`` (ExFlow-like) differs only via the profile-optimized
    placement baked into ``topo`` — the dispatch itself is round-robin.
    ``extra_local`` (replica-slot placements) keeps sources' own units
    home for replica-resident experts and widens the harmoeny
    rebalancer's destination set; ``non_local`` (tiered residency)
    demotes statically-local experts whose weights are swapped out of
    HBM so the rebalancer stops treating them as free destinations.
    The baselines ignore both.
    """
    if policy == "harmoeny":
        S0 = initial_assign(counts, topo, extra_local=extra_local)
        return rebalance(S0, topo, q=q, c_pair=c_pair,
                         num_foreign_slots=num_foreign_slots,
                         max_iters=max_iters, extra_local=extra_local,
                         non_local=non_local)
    S0 = initial_assign(counts, topo)
    if policy in ("round_robin", "static_opt"):
        zero = jnp.int32(0)
        t_g = S0.sum(axis=(0, 1))
        return S0, ScheduleDiag(zero, zero, t_g.max(), t_g.max())
    if policy == "even_split":
        S = even_split(counts, topo)
        zero = jnp.int32(0)
        return S, ScheduleDiag(zero, zero,
                               S0.sum(axis=(0, 1)).max(), S.sum(axis=(0, 1)).max())
    raise ValueError(f"unknown policy {policy!r}")
