"""Deterministic v5e time model for schedule quality (benchmark backend).

Wall-clock on this container (1 CPU core, fake devices) is meaningless for
absolute claims, so the benchmarks evaluate policies with (i) exact schedule
metrics (loads, drops, moves) from the real scheduler, and (ii) this
calibrated per-rank time model:

  compute[g]  = load[g] * unit_flops / peak_flops        (MoE expert math)
  fetch[g]    = n_foreign[g] * expert_bytes * fetch_penalty / ici_bw,
                overlapped with compute (paper §4.3): busy = max(comp, fetch)
  a2a         = max_g off-diagonal payload bytes / ici_bw   (x2: scatter+gather)
  metadata    = G*E*4 bytes / ici_bw + launch latency
  scheduler   = rebalance iterations * per-iter cost (on-device while loop)

  layer time  = max_g busy[g] + a2a + metadata + scheduler
  idle[g]     = layer - busy[g]   (the paper's Fig. 5/11 waiting time)

The same model underlies the q-threshold discussion (Eq. 4): fetch is
maskable iff compute >= fetch, i.e. load >= q.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.qthreshold import HardwareSpec, V5E
from repro.core.topology import EPTopology, local_slot_of


@dataclass(frozen=True)
class SimCosts:
    hw: HardwareSpec = V5E
    d_model: int = 768
    d_ff: int = 3072
    n_matrices: int = 2          # 2 for gelu MLP, 3 for swiglu
    dtype_bytes: int = 2
    # Fetch transport model: a peer-HBM DMA read over ICI (x2 for link
    # contention). The current XLA implementation pays ~G x via the dense
    # all_to_all (zeros ride the wire); a Pallas RDMA fetch would reach this
    # model's cost — tracked in EXPERIMENTS.md §Perf as the gap between the
    # compiled collective bytes and this target.
    fetch_penalty: float = 2.0
    sched_iter_us: float = 0.15  # argmax/update over S[G,E,G] per iteration
    launch_us: float = 5.0
    mfu: float = 0.4             # achievable fraction of peak on expert GEMMs
    # Host-tier staging (tiered residency, serve/residency.py): experts
    # swapped out of HBM are fetched from host DRAM over PCIe gen4 x16 —
    # an order of magnitude slower than the peer-HBM ICI path above.
    host_bw: float = 32e9

    @property
    def unit_flops(self) -> float:
        return 2.0 * self.d_model * self.d_ff * self.n_matrices

    @property
    def expert_bytes(self) -> float:
        return self.n_matrices * self.d_model * self.d_ff * self.dtype_bytes


def simulate_layer(S: np.ndarray, topo: EPTopology, costs: SimCosts,
                   sched_iters: int = 0, drops: int = 0,
                   extra_local: np.ndarray | None = None,
                   non_local: np.ndarray | None = None,
                   hidden_stages: np.ndarray | None = None) -> Dict[str, float]:
    """S: [G, Ep, G] schedule. Returns per-layer timing + balance metrics.

    ``extra_local`` [G, Ep] bool marks experts whose weights are already
    resident at a destination beyond its static shard — the hot-expert
    replica slots (serve/rebalance.py).  Units scheduled there cost
    compute but no fetch, which is exactly the replication win the time
    model has to credit.

    ``non_local`` [G, Ep] bool demotes statically-placed experts whose
    weights are currently swapped out of HBM (tiered residency,
    serve/residency.py): units scheduled to a demoted pair pay a
    host-tier fetch (``expert_bytes / host_bw`` — PCIe, not ICI) unless
    ``hidden_stages`` [G, Ep] marks the miss as prefetched ahead of use,
    in which case the transfer overlaps the previous layer's compute and
    only the bytes (not the stall) are charged."""
    G = topo.num_ranks
    S = np.asarray(S)
    load = S.sum(axis=(0, 1)).astype(np.float64)               # per dest
    lsl = local_slot_of(topo).copy()
    if extra_local is not None:
        lsl = np.where(np.asarray(extra_local), np.maximum(lsl, 0), lsl)
    active = np.array([[S[:, e, g].sum() > 0
                        for e in range(topo.padded_experts)]
                       for g in range(G)])                     # [G, Ep]
    demoted = np.zeros_like(active)
    if non_local is not None:
        demoted = np.asarray(non_local) & (lsl >= 0)
        if hidden_stages is not None:
            demoted = demoted & ~np.asarray(hidden_stages)
        lsl = np.where(np.asarray(non_local), -1, lsl)
    foreign = (active & (lsl < 0) & ~demoted).sum(axis=1)
    host_misses = (active & demoted).sum(axis=1)

    comp = load * costs.unit_flops / (costs.hw.peak_flops * costs.mfu)
    fetch = foreign * costs.expert_bytes * costs.fetch_penalty / costs.hw.ici_bw \
        + host_misses * costs.expert_bytes / costs.host_bw
    busy = np.maximum(comp, fetch)

    offdiag = S.sum(axis=1) * (1 - np.eye(G, dtype=np.int64))
    a2a_bytes = max(offdiag.sum(axis=1).max(), offdiag.sum(axis=0).max()) \
        * costs.d_model * costs.dtype_bytes
    a2a = 2.0 * a2a_bytes / costs.hw.ici_bw
    metadata = (G * topo.padded_experts * 4) / costs.hw.ici_bw \
        + costs.launch_us * 1e-6
    sched = sched_iters * costs.sched_iter_us * 1e-6 + costs.launch_us * 1e-6

    layer = busy.max() + a2a + metadata + sched
    idle = layer - busy
    total_units = float(S.sum())
    return {
        "layer_s": float(layer),
        "compute_s": float(comp.max()),
        "fetch_s": float(fetch.max()),
        "host_stall_s": float(
            (host_misses * costs.expert_bytes / costs.host_bw).max()),
        "a2a_s": float(a2a),
        "sched_s": float(sched),
        "metadata_s": float(metadata),
        "idle_frac_mean": float(idle.mean() / layer) if layer > 0 else 0.0,
        "idle_frac_max": float(idle.max() / layer) if layer > 0 else 0.0,
        "max_load": float(load.max()),
        "mean_load": float(load.mean()),
        "imbalance": float(load.max() / max(load.mean(), 1e-9)),
        "tokens_per_s": total_units / layer if layer > 0 else 0.0,
        "dropped": float(drops),
    }
