"""Expert-parallel topology: static expert placement and slot maps.

Ranks are the positions along the EP ("model") mesh axis. Experts are padded
to a multiple of the EP degree so every rank owns the same number of local
slots; padded (dummy) experts are never routed to.

Two regimes:
  * E >= G (switch128, moonshot, qwen):  experts_per_rank = Ep // G, expert e
    lives on rank ``e % G`` (DeepSpeed-style round-robin).
  * E <  G (mixtral-8x7b on a 16-wide EP axis): each expert is replicated on
    ``G // E`` host ranks; rank g hosts expert ``g % E``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import round_up


@dataclass(frozen=True)
class EPTopology:
    num_ranks: int            # G: EP degree (size of the 'model' axis)
    num_experts: int          # E: real experts
    padded_experts: int       # Ep: round_up(E, G) when E >= G else E
    experts_per_rank: int     # local slots per rank
    hosts_per_expert: int     # replication factor (1 when E >= G)
    slot_map: np.ndarray      # [G, experts_per_rank] expert id of each local slot
    host_of: np.ndarray       # [Ep, hosts_per_expert] host ranks of each expert

    @property
    def is_replicated(self) -> bool:
        return self.hosts_per_expert > 1


def make_topology(num_ranks: int, num_experts: int,
                  placement: np.ndarray | None = None) -> EPTopology:
    """Build the static placement.

    ``placement`` optionally permutes experts onto slots (the ExFlow-like
    ``static_opt`` policy passes a profile-optimized permutation [Ep]).
    """
    G = int(num_ranks)
    E = int(num_experts)
    if E >= G:
        Ep = round_up(E, G)
        epr = Ep // G
        perm = np.arange(Ep) if placement is None else np.asarray(placement)
        assert perm.shape == (Ep,)
        # slot (g, j) hosts expert perm[j * G + g]  (round-robin over ranks)
        slot_map = perm.reshape(epr, G).T.copy()          # [G, epr]
        host_of = np.zeros((Ep, 1), np.int64)
        for g in range(G):
            for j in range(epr):
                host_of[slot_map[g, j], 0] = g
        return EPTopology(G, E, Ep, epr, 1, slot_map.astype(np.int32),
                          host_of.astype(np.int32))
    else:
        assert G % E == 0, f"EP degree {G} must be a multiple of num_experts {E}"
        r = G // E
        slot_map = (np.arange(G) % E).reshape(G, 1)
        host_of = np.zeros((E, r), np.int64)
        for e in range(E):
            host_of[e] = np.arange(r) * E + e
        return EPTopology(G, E, E, 1, r, slot_map.astype(np.int32),
                          host_of.astype(np.int32))


def local_slot_of(topo: EPTopology) -> np.ndarray:
    """[G, Ep] -> local slot index of expert e on rank g, or -1 if not hosted."""
    out = -np.ones((topo.num_ranks, topo.padded_experts), np.int32)
    for g in range(topo.num_ranks):
        for j in range(topo.experts_per_rank):
            out[g, topo.slot_map[g, j]] = j
    return out


def static_opt_placement(profile_counts: np.ndarray, num_ranks: int) -> np.ndarray:
    """ExFlow-like offline placement: greedy bin-packing of expert popularity.

    ``profile_counts`` [E] from a held-out profile batch. Returns a
    permutation [Ep] such that popular experts are spread across ranks:
    experts sorted by popularity are dealt round-robin into rank bins in a
    snake order (largest-processing-time heuristic of the IP the paper's
    ExFlow baseline solves offline).
    """
    E = profile_counts.shape[0]
    Ep = round_up(E, num_ranks)
    counts = np.zeros(Ep)
    counts[:E] = profile_counts
    order = np.argsort(-counts)                 # most popular first
    epr = Ep // num_ranks
    # snake-deal into G bins to equalize bin sums
    bins: list[list[int]] = [[] for _ in range(num_ranks)]
    loads = np.zeros(num_ranks)
    for e in order:
        g = int(np.argmin(loads))
        if len(bins[g]) >= epr:               # bin full: next least-loaded with room
            cand = [i for i in range(num_ranks) if len(bins[i]) < epr]
            g = cand[int(np.argmin(loads[cand]))]
        bins[g].append(int(e))
        loads[g] += counts[e]
    # perm[j*G + g] = expert in slot j of rank g
    perm = np.zeros(Ep, np.int64)
    for g in range(num_ranks):
        for j in range(epr):
            perm[j * num_ranks + g] = bins[g][j]
    return perm
