"""Serving metrics: per-request latency accounting plus per-step MoE
schedule diagnostics.

Per request (the paper's §5 serving metrics):
  * TTFT — first_token_time - arrival_time (queueing + prefill)
  * TPOT — mean inter-token time over the decode phase
  * e2e  — finish_time - arrival_time

Per step, the engine feeds in the HarMoEny schedule diagnostics emitted by
the MoE block (moved_units, send/dest drops, max load before/after) and the
number of occupied decode slots, so batch-occupancy and load-balance
trajectories can be plotted against arrival rate and skew.

The paged engine additionally records per-step KV-block occupancy
(``record_kv``) and preemption counts, reported as ``kv_blocks_in_use`` /
``kv_utilization`` / ``preemptions``.  Speculative decoding records
drafted/accepted/committed token counts per verify step, reported as a
``speculative`` sub-dict (acceptance_rate, tokens per slot-step, steps
per committed token).  ``record_phase`` accumulates a per-phase kernel
breakdown (prefill / prefix_tail / decode / verify tokens-per-second and
analytic attention KV bytes-touched), reported as ``phases``.
With tiered expert residency the engine attaches a ``residency``
sub-dict (hit_rate, stall_units, swaps, prefetches, bytes_staged) from
the residency manager's window counters.  ``report()`` is JSON-safe on
an empty measurement window: percentile reductions over zero requests
come back as ``None``, never NaN.

**Section convention.**  Every optional subsystem block in a report —
``speculative``, ``phases``, ``load_balance``, ``residency`` here;
``state_pool`` from the engine; ``fleet`` from the router — attaches
through one mechanism instead of ad-hoc conditional appends: a *section
function* returns the section dict, or a falsy value to omit the section
this window.  ``ServeMetrics.register_section(name, fn)`` registers one
on a metrics object (the built-ins register themselves the same way at
construction, so subsystem sections and core sections are
indistinguishable in ``report()``); the module-level ``section(rep,
name, fn)`` applies the identical rule to dicts assembled outside a
``ServeMetrics`` (the engine's and the fleet router's reports).  The
full schema is documented in serve/README.md ("Report schema").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.serve.request import RequestState

# a report section provider: () -> the section dict, or falsy to omit
SectionFn = Callable[[], Optional[Dict[str, Any]]]


def section(rep: Dict[str, Any], name: str, fn: SectionFn) -> None:
    """Attach ``fn()`` to ``rep`` under ``name`` iff non-empty — the one
    convention every subsystem report section goes through (see module
    docstring)."""
    sec = fn()
    if sec:
        rep[name] = sec


def percentiles(xs, ps=(50, 90, 99)) -> Dict[str, float]:
    xs = np.asarray(list(xs), np.float64)
    if xs.size == 0:
        return {f"p{p}": float("nan") for p in ps} | {"mean": float("nan")}
    out = {f"p{p}": float(np.percentile(xs, p)) for p in ps}
    out["mean"] = float(xs.mean())
    return out


def _json_safe(x):
    """Recursively replace non-finite floats with None so an empty window's
    report serializes under ``json.dumps(..., allow_nan=False)``."""
    if isinstance(x, dict):
        return {k: _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, float) and not np.isfinite(x):
        return None
    return x


@dataclass
class RequestRecord:
    """Immutable latency record for one finished request."""
    rid: int
    prompt_len: int
    n_generated: int
    arrival_time: float
    admitted_time: float
    first_token_time: float
    finish_time: float
    cached_prefix_tokens: int = 0   # prompt tokens served from the prefix
    #                                 cache at first admission

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> float:
        if self.n_generated <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) \
            / (self.n_generated - 1)

    @property
    def e2e(self) -> float:
        return self.finish_time - self.arrival_time

    def asdict(self) -> Dict[str, float]:
        return {
            "rid": self.rid, "prompt_len": self.prompt_len,
            "n_generated": self.n_generated,
            "arrival_time": self.arrival_time,
            "queue_delay": self.admitted_time - self.arrival_time,
            "ttft": self.ttft, "tpot": self.tpot, "e2e": self.e2e,
            "cached_prefix_tokens": self.cached_prefix_tokens,
        }


class ServeMetrics:
    """Accumulates request records and per-step diagnostics."""

    def __init__(self):
        self.requests: List[RequestRecord] = []
        self.decode_steps: int = 0
        self.prefill_chunks: int = 0
        self.occupancy: List[int] = []          # active slots per decode step
        self.moe_diags: Dict[str, List[float]] = {}
        # vector-valued MoE diagnostics (per-rank rank_load [G], per-expert
        # expert_load [Ep]) — kept per step for the load_balance report
        self.load_vectors: Dict[str, List[np.ndarray]] = {}
        self.kv_blocks_in_use: List[int] = []   # per decode step (paged)
        self.kv_blocks_total: int = 0
        self.preemptions: int = 0
        # --- prefix sharing (paged) ---
        self.cow_copies: int = 0                # copy-on-write block copies
        self.evictions: int = 0                 # cached prefixes evicted
        self.resume_cached_tokens: int = 0      # prefill skipped on resume
        # --- speculative decoding ---
        self.spec_steps: int = 0                # verify steps run
        self.spec_slot_steps: int = 0           # active-slot verify passes
        self.spec_drafted: int = 0              # draft tokens proposed
        self.spec_accepted: int = 0             # draft tokens accepted
        self.spec_committed: int = 0            # tokens committed by verify
        # --- per-phase kernel accounting (prefill / prefix_tail / decode /
        # verify): committed-or-consumed tokens, wall seconds around the
        # jitted call, and the analytic KV bytes the attention path read
        # (gather: the whole [B, L_max] logical view; fused: the live
        # block-rounded chains) — the measured artifact behind the "no hot
        # phase dispatches the logical gather" claim
        self.phase_tokens: Dict[str, int] = {}
        self.phase_seconds: Dict[str, float] = {}
        self.phase_kv_bytes: Dict[str, int] = {}
        self.phase_steps: Dict[str, int] = {}
        # --- tiered expert residency (serve/residency.py) ---
        # window counter dict (hits, misses, lookups, swaps, prefetches,
        # stall_units, bytes_staged, hit_rate) set by the engine's
        # report() from the residency manager; None = residency off
        self.residency: Optional[Dict[str, Any]] = None
        self._t_first_arrival: Optional[float] = None
        self._t_last_finish: float = 0.0
        # --- report sections (module docstring "Section convention") ---
        # name -> provider; report() attaches each non-empty result.  The
        # built-in subsystem sections register through the same mechanism
        # engine-side sections (state_pool) do.
        self._sections: Dict[str, SectionFn] = {}
        self.register_section("speculative", self._speculative_section)
        self.register_section("phases", self._phases_section)
        self.register_section("residency",
                              lambda: self.residency
                              and dict(self.residency))
        self.register_section("load_balance", self._load_balance)

    def register_section(self, name: str, fn: SectionFn) -> None:
        """Register a report section provider (last registration per name
        wins).  ``fn()`` runs at ``report()`` time; a falsy return omits
        the section for this window."""
        self._sections[name] = fn

    @property
    def empty(self) -> bool:
        """No timestamps recorded yet — the measurement window is fresh."""
        return not (self.requests or self.decode_steps or self.prefill_chunks)

    # ------------------------------------------------------------------
    def record_step(self, diags: Dict[str, Any], n_active: int,
                    phase: str = "decode") -> None:
        if phase == "decode":
            self.decode_steps += 1
            self.occupancy.append(n_active)
        else:
            self.prefill_chunks += 1
        for k, v in (diags or {}).items():
            arr = np.asarray(v)
            if arr.ndim:
                self.load_vectors.setdefault(f"{phase}/{k}", []).append(
                    arr.reshape(-1).astype(np.float64))
            else:
                self.moe_diags.setdefault(f"{phase}/{k}", []).append(
                    float(arr))

    def record_phase(self, phase: str, tokens: int, seconds: float,
                     kv_bytes: int) -> None:
        """One engine step's contribution to a phase (prefill /
        prefix_tail / decode / verify): tokens processed, wall seconds
        around the synced jitted call, analytic attention KV bytes."""
        self.phase_tokens[phase] = self.phase_tokens.get(phase, 0) \
            + int(tokens)
        self.phase_seconds[phase] = self.phase_seconds.get(phase, 0.0) \
            + float(seconds)
        self.phase_kv_bytes[phase] = self.phase_kv_bytes.get(phase, 0) \
            + int(kv_bytes)
        self.phase_steps[phase] = self.phase_steps.get(phase, 0) + 1

    def record_kv(self, blocks_in_use: int, blocks_total: int) -> None:
        """Per-decode-step KV-block occupancy of the paged pool."""
        self.kv_blocks_in_use.append(int(blocks_in_use))
        self.kv_blocks_total = int(blocks_total)

    def complete(self, st: RequestState) -> RequestRecord:
        rec = RequestRecord(
            rid=st.req.rid, prompt_len=st.req.prompt_len,
            n_generated=st.n_generated,
            arrival_time=st.req.arrival_time,
            admitted_time=st.admitted_time,
            first_token_time=st.first_token_time,
            finish_time=st.finish_time,
            cached_prefix_tokens=st.cached_prefix_tokens or 0)
        self.requests.append(rec)
        if self._t_first_arrival is None \
                or rec.arrival_time < self._t_first_arrival:
            self._t_first_arrival = rec.arrival_time
        self._t_last_finish = max(self._t_last_finish, rec.finish_time)
        return rec

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        recs = self.requests
        total_new = sum(r.n_generated for r in recs)
        total_prompt = sum(r.prompt_len for r in recs)
        span = (self._t_last_finish - self._t_first_arrival) \
            if recs and self._t_first_arrival is not None else 0.0
        rep: Dict[str, Any] = {
            "n_requests": len(recs),
            "total_new_tokens": total_new,
            "ttft": percentiles(r.ttft for r in recs),
            "tpot": percentiles(r.tpot for r in recs if r.n_generated > 1),
            "e2e": percentiles(r.e2e for r in recs),
            "queue_delay": percentiles(
                r.admitted_time - r.arrival_time for r in recs),
            "throughput_tok_s": total_new / span if span > 0 else float("nan"),
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "mean_occupancy": (float(np.mean(self.occupancy))
                               if self.occupancy else 0.0),
            "max_occupancy": (int(max(self.occupancy))
                              if self.occupancy else 0),
            "preemptions": self.preemptions,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
            "resume_cached_tokens": self.resume_cached_tokens,
            # token-level prefix cache hit rate: prompt tokens whose K/V was
            # mapped from the cache at first admission / all prompt tokens
            "prefix_hit_rate": (
                sum(r.cached_prefix_tokens for r in recs) / total_prompt
                if total_prompt else None),
            "requests": [r.asdict() for r in recs],
        }
        if self.kv_blocks_in_use:
            used = np.asarray(self.kv_blocks_in_use, np.float64)
            rep["kv_blocks_in_use"] = {"mean": float(used.mean()),
                                       "max": int(used.max())}
            rep["kv_utilization"] = (float(used.mean())
                                     / max(self.kv_blocks_total, 1))
        if self.moe_diags:
            rep["moe"] = {k: float(np.mean(v))
                          for k, v in self.moe_diags.items()}
        for name, fn in self._sections.items():
            section(rep, name, fn)
        return _json_safe(rep)

    def _speculative_section(self) -> Optional[Dict[str, Any]]:
        if not self.spec_steps:
            return None
        # the per-SLOT accounting is what isolates speculation from
        # batching: plain decode spends exactly one slot-step per
        # committed token, so tokens_per_step == 1.0 marks "no win"
        # regardless of how many slots each wall-clock step batches
        return {
                "steps": self.spec_steps,
                "slot_steps": self.spec_slot_steps,
                "drafted": self.spec_drafted,
                "accepted": self.spec_accepted,
                "committed_tokens": self.spec_committed,
                # share of proposed drafts the verify step kept
                "acceptance_rate": (self.spec_accepted / self.spec_drafted
                                    if self.spec_drafted else None),
                # committed tokens per active-slot verify pass
                # (> 1.0 is the speculative win)
                "tokens_per_step": (self.spec_committed
                                    / self.spec_slot_steps
                                    if self.spec_slot_steps else None),
                # < 1.0 is the speculative win, mirrored for the paper's
                # steps-per-token framing
                "steps_per_committed_token": (
                    self.spec_slot_steps / self.spec_committed
                    if self.spec_committed else None),
        }

    def _phases_section(self) -> Optional[Dict[str, Any]]:
        if not self.phase_steps:
            return None
        return {
                ph: {
                    "steps": self.phase_steps[ph],
                    "tokens": self.phase_tokens.get(ph, 0),
                    "seconds": self.phase_seconds.get(ph, 0.0),
                    "tokens_per_s": (
                        self.phase_tokens.get(ph, 0)
                        / self.phase_seconds[ph]
                        if self.phase_seconds.get(ph, 0.0) > 0
                        else None),
                    "kv_bytes_touched": self.phase_kv_bytes.get(ph, 0),
                    "kv_bytes_per_token": (
                        self.phase_kv_bytes.get(ph, 0)
                        / self.phase_tokens[ph]
                        if self.phase_tokens.get(ph, 0) else None),
                }
                for ph in sorted(self.phase_steps)
        }

    def _load_balance(self) -> Dict[str, Any]:
        """Paper §5 load metrics per phase, from the per-step vector
        diagnostics: mean per-rank/per-expert load profiles, the max/mean
        rank-load ratio (1.0 = perfect balance), the straggler-wait proxy
        (mean of max - mean scheduled units per step — the token units the
        average rank sits idle while the most-loaded rank finishes, the
        static-shape analogue of the paper's GPU idle time), and total
        scheduler drop counts."""
        out: Dict[str, Any] = {}
        for phase in ("decode", "prefill"):
            rl = self.load_vectors.get(f"{phase}/rank_load")
            el = self.load_vectors.get(f"{phase}/expert_load")
            if rl is None and el is None:
                continue
            sec: Dict[str, Any] = {}
            if rl:
                m = np.stack(rl)                      # [steps, G]
                mx, mn = m.max(axis=1), m.mean(axis=1)
                sec["rank_load_mean"] = m.mean(axis=0).tolist()
                sec["max_load_mean"] = float(mx.mean())
                sec["mean_load_mean"] = float(mn.mean())
                sec["max_mean_ratio"] = float(np.mean(
                    np.where(mn > 0, mx / np.maximum(mn, 1e-9), 1.0)))
                sec["straggler_wait_units"] = float(np.mean(mx - mn))
            if el:
                e = np.stack(el)                      # [steps, Ep]
                sec["expert_load_mean"] = e.mean(axis=0).tolist()
            for drop in ("send_drops", "dest_drops"):
                vals = self.moe_diags.get(f"{phase}/{drop}")
                if vals is not None:
                    sec[f"{drop}_total"] = float(np.sum(vals))
            out[phase] = sec
        return out


# ----------------------------------------------------------------------
def aggregate_fleet(reports: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Pool several engine ``report()`` dicts into fleet-level latency
    aggregates.  Works on the JSON-safe per-request rows each report
    carries, so it composes across replicas regardless of role: in a
    disaggregated fleet the decode engines own the completion records
    (handoffs carry the true arrival/TTFT timestamps across), so summing
    per-replica rows double-counts nothing.  All replicas must share one
    clock — the timestamps are only comparable on a common timebase."""
    rows = [r for rep in reports for r in rep.get("requests", ())]
    total_new = sum(r["n_generated"] for r in rows)
    finishes = [r["arrival_time"] + r["e2e"] for r in rows]
    span = (max(finishes) - min(r["arrival_time"] for r in rows)
            if rows else 0.0)
    agg: Dict[str, Any] = {
        "n_requests": len(rows),
        "total_new_tokens": total_new,
        "ttft": percentiles(r["ttft"] for r in rows),
        "tpot": percentiles(r["tpot"] for r in rows
                            if r["n_generated"] > 1),
        "e2e": percentiles(r["e2e"] for r in rows),
        "queue_delay": percentiles(r["queue_delay"] for r in rows),
        "throughput_tok_s": total_new / span if span > 0
        else float("nan"),
        # goodput: finished requests per second of fleet wall time — the
        # serving papers' service-level throughput
        "goodput_req_s": len(rows) / span if span > 0 else float("nan"),
        "preemptions": sum(rep.get("preemptions", 0) for rep in reports),
        "prefix_hit_rate": (
            sum(r["cached_prefix_tokens"] for r in rows)
            / sum(r["prompt_len"] for r in rows)
            if sum(r["prompt_len"] for r in rows) else None),
    }
    return _json_safe(agg)
