"""Slotted KV pool: ``model.init_cache`` reinterpreted as a slab of
per-request slots.

The pool is one static-shape cache pytree of batch ``n_slots``; each row is
a slot that a request occupies from admission until EOS/max-len, after which
it is recycled for a queued request. Prefill runs against a batch-1 scratch
cache (same per-layer shapes) and the finished prefix is scattered into the
slot with ``write_slot`` — a traced-index ``dynamic_update_slice``, so slot
recycling never triggers recompilation.

Cache layouts differ per leaf (scan-stacked blocks put batch at axis 1,
unscanned lead layers at axis 0), so the batch axis of every leaf is
discovered structurally: ``init_cache`` is shape-evaluated at two batch
sizes and the differing axis is the batch axis.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def discover_batch_axes(init_cache: Callable[[int, int], Any],
                        s_max: int) -> Any:
    """Pytree of per-leaf batch-axis indices for ``init_cache`` outputs."""
    a = jax.eval_shape(lambda: init_cache(2, s_max))
    b = jax.eval_shape(lambda: init_cache(3, s_max))

    def axis(la, lb):
        diffs = [i for i, (x, y) in enumerate(zip(la.shape, lb.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"cannot identify batch axis for cache leaf {la.shape} "
                f"vs {lb.shape}")
        return diffs[0]

    return jax.tree.map(axis, a, b)


def min_kv_capacity(init_cache: Callable[[int, int], Any], s_max: int,
                    batch_axes: Any) -> int:
    """Smallest per-layer KV length in the pool (sliding-window layers clamp
    their cache to the window, so prefill writes must fit the minimum)."""
    shapes = jax.eval_shape(lambda: init_cache(1, s_max))
    caps = []
    jax.tree.map(
        lambda leaf, ax: caps.append(leaf.shape[ax + 1]), shapes, batch_axes)
    return min(caps)


def write_slot(pool: Any, scratch: Any, slot: jnp.ndarray,
               batch_axes: Any) -> Any:
    """Scatter the batch-1 ``scratch`` cache into row ``slot`` of ``pool``.

    ``slot`` is a traced int32 scalar — one compilation serves every slot.
    """
    def upd(p, sc, ax):
        pm = jnp.moveaxis(p, ax, 0)
        sm = jnp.moveaxis(sc, ax, 0).astype(pm.dtype)
        pm = jax.lax.dynamic_update_slice(
            pm, sm, (slot,) + (0,) * (pm.ndim - 1))
        return jnp.moveaxis(pm, 0, ax)

    return jax.tree.map(upd, pool, scratch, batch_axes)
