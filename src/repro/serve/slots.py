"""Slotted KV pool: ``model.init_cache`` reinterpreted as a slab of
per-request slots.

The pool is one static-shape cache pytree of batch ``n_slots``; each row is
a slot that a request occupies from admission until EOS/max-len, after which
it is recycled for a queued request. Prefill runs against a batch-1 scratch
cache (same per-layer shapes) and the finished prefix is scattered into the
slot with ``write_slot`` — a traced-index ``dynamic_update_slice``, so slot
recycling never triggers recompilation.

Cache layouts differ per leaf (scan-stacked blocks put batch at axis 1,
unscanned lead layers at axis 0), so the batch axis AND the KV-length axis
of every leaf are discovered structurally: ``init_cache`` is
shape-evaluated at two batch sizes (resp. two ``s_max`` values) and the
differing axis is the one sought — neither is assumed adjacent to the
other.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def _differing_axes(la, lb) -> list:
    """Axis indices where two shape-evaluated leaves disagree."""
    return [i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y]


def discover_batch_axes(init_cache: Callable[[int, int], Any],
                        s_max: int) -> Any:
    """Pytree of per-leaf batch-axis indices for ``init_cache`` outputs."""
    a = jax.eval_shape(lambda: init_cache(2, s_max))
    b = jax.eval_shape(lambda: init_cache(3, s_max))

    def axis(la, lb):
        diffs = _differing_axes(la, lb)
        if len(diffs) != 1:
            raise ValueError(
                f"cannot identify batch axis for cache leaf {la.shape} "
                f"vs {lb.shape}")
        return diffs[0]

    return jax.tree.map(axis, a, b)


def discover_seq_axes(init_cache: Callable[[int, int], Any],
                      s_max: int) -> Any:
    """Pytree of per-leaf KV-length-axis indices for ``init_cache`` outputs,
    found structurally like the batch axis (never assumed adjacent to it):
    shape-evaluate at two ``s_max`` values and take the differing axis.

    Sliding-window layers clamp their cache to ``min(s_max, window)``, so a
    leaf that is s_max-invariant at (s_max, s_max + 1) is probed again at
    (1, 2), below any window. A leaf whose shape depends on ``s_max`` at
    neither probe (e.g. an SSM state) has no KV-length axis and is marked
    ``-1`` (a real -1 sentinel, not ``None``, which jax pytrees treat as an
    empty subtree).
    """
    probes = [(s_max, s_max + 1), (1, 2)]
    trees = [jax.eval_shape(lambda s=s: init_cache(1, s))
             for pair in probes for s in pair]

    def axis(la_hi, lb_hi, la_lo, lb_lo):
        for la, lb in ((la_hi, lb_hi), (la_lo, lb_lo)):
            diffs = _differing_axes(la, lb)
            if len(diffs) == 1:
                return diffs[0]
            if len(diffs) > 1:
                raise ValueError(
                    f"cannot identify KV-length axis for cache leaf "
                    f"{la.shape} vs {lb.shape}")
        return -1

    return jax.tree.map(axis, *trees)


def min_kv_capacity(init_cache: Callable[[int, int], Any], s_max: int,
                    seq_axes: Any, default: int = 0) -> int:
    """Smallest per-layer KV length in the pool (sliding-window layers clamp
    their cache to the window, so prefill writes must fit the minimum).
    Leaves without a KV-length axis (marked ``-1``) impose no capacity; a
    cache with *no* seq-axed leaf at all (pure SSM state — fixed-size per
    slot) returns ``default`` when given, else raises."""
    shapes = jax.eval_shape(lambda: init_cache(1, s_max))
    caps = []
    jax.tree.map(
        lambda leaf, ax: caps.append(leaf.shape[ax]) if ax >= 0 else None,
        shapes, seq_axes)
    if not caps:
        if default:
            return default
        raise ValueError("no cache leaf depends on s_max; cannot size the "
                         "KV pool")
    return min(caps)


def write_slot(pool: Any, scratch: Any, slot: jnp.ndarray,
               batch_axes: Any) -> Any:
    """Scatter the batch-1 ``scratch`` cache into row ``slot`` of ``pool``.

    ``slot`` is a traced int32 scalar — one compilation serves every slot.
    """
    def upd(p, sc, ax):
        pm = jnp.moveaxis(p, ax, 0)
        sm = jnp.moveaxis(sc, ax, 0).astype(pm.dtype)
        pm = jax.lax.dynamic_update_slice(
            pm, sm, (slot,) + (0,) * (pm.ndim - 1))
        return jnp.moveaxis(pm, 0, ax)

    return jax.tree.map(upd, pool, scratch, batch_axes)
