"""Tiered expert residency with asynchronous prefetch (paper §4.3).

HarMoEny's contribution (ii): when expert weights exceed device memory,
keep only a bounded *working set* of each rank's experts resident in HBM
and stream the rest in from a slower tier (host DRAM over PCIe) *ahead of
use*, predicted from the previous layer's router decisions, so the
transfer overlaps compute instead of serializing with it.

This module is the host-side half of that mechanism. The tier split is
emulated the same way ``BENCH_serve.json`` carries modeled cells: device
parameters stay authoritative (compute is bit-exact regardless of the
residency state — greedy streams are token-identical across budgets by
construction), while an explicit host-side copy of the expert rows plus a
:class:`TierCostModel` account for the PCIe traffic and stalls the real
tiering would incur. What *is* real: the ``[G, W]`` residency table rides
into the one decode jit entry as a traced argument (swaps never
recompile), non-resident experts are demoted to fetch-paying work in the
HarMoEny scheduler via a ``non_local`` mask, and staging runs through a
jitted scatter dispatched *before* the decode step so jax's async
dispatch double-buffers the transfer against compute.

Three pieces:

  * :class:`ResidencyCache` — a per-rank pinned-LRU cache over the rank's
    own expert shard. Pure bookkeeping (no arrays), which makes it the
    property-fuzz target: budget is never exceeded, pinned experts are
    never evicted, ``hits + misses == lookups``, and evictions follow
    least-recently-used order.

  * :class:`ExpertResidencyManager` — folds the per-layer ``expert_load``
    diagnostic into a *per-layer* EMA (the PR-6 follow-on signal; see
    ``ExpertRebalancer.observe(layer=...)``), replays each engine step
    layer by layer against the caches, and emits a
    :class:`ResidencyDecision`: the next ``[G, W]`` residency table, the
    stacked weight rows to stage, and the step's hit/stall/bytes
    accounting. Under the ``predictive`` policy, layer ``l``'s compute
    window prefetches the experts the EMA predicts layer ``l+1`` will
    route to — a predicted miss costs bytes but *no stall*; ``on_demand``
    stages on first touch and stalls every time; ``none`` freezes the
    initial working set and stalls on every non-resident use.

  * :class:`TierCostModel` — expert bytes / PCIe bandwidth, mirroring
    ``core/simulator.SimCosts`` (which grew ``host_bw`` so
    ``simulate_layer(non_local=)`` prices the same tier).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.topology import EPTopology, local_slot_of

PREFETCH_POLICIES = ("predictive", "on_demand", "none")


@dataclasses.dataclass(frozen=True)
class TierCostModel:
    """Modeled host→HBM staging cost (PCIe gen4 x16 by default)."""
    expert_bytes: float = 0.0      # bytes per expert's weight rows (per rank)
    pcie_bw: float = 16e9          # host→device link, bytes/s

    def stall_units(self, n_experts: int) -> float:
        """Seconds of serialized transfer for ``n_experts`` demand misses."""
        if self.expert_bytes <= 0.0:
            return float(n_experts)          # unit-cost fallback (tests)
        return n_experts * self.expert_bytes / self.pcie_bw


class ResidencyCache:
    """Pinned-LRU working set over one rank's expert shard.

    Pure counter/ordering bookkeeping — the fuzz target for
    ``tests/test_residency_properties.py``. ``capacity`` is the HBM
    budget W (slots); ``experts`` the ids eligible to be cached (the
    rank's own static shard). Pinning marks the experts the *current*
    layer is routing to: they may not be evicted mid-step, so a stage
    that would require evicting a pinned expert fails (returns None)
    rather than corrupting in-flight compute.
    """

    def __init__(self, capacity: int, experts: Sequence[int]):
        if capacity <= 0:
            raise ValueError("residency capacity must be > 0")
        self.capacity = int(capacity)
        self.eligible = frozenset(int(e) for e in experts)
        if self.capacity > len(self.eligible):
            raise ValueError(
                f"capacity {capacity} exceeds shard size {len(self.eligible)}")
        self._lru: List[int] = []         # least-recent first
        self._pinned: set = set()
        self.hits = 0
        self.misses = 0
        self.lookups = 0
        self.evictions = 0
        self.stages = 0

    # ------------------------------------------------------------- state
    @property
    def resident(self) -> List[int]:
        """Resident experts, least-recently-used first."""
        return list(self._lru)

    def __contains__(self, e: int) -> bool:
        return int(e) in set(self._lru)

    def __len__(self) -> int:
        return len(self._lru)

    # ---------------------------------------------------------------- ops
    def lookup(self, e: int) -> bool:
        """Count a use of expert ``e``; True = hit (refreshes recency)."""
        e = int(e)
        if e not in self.eligible:
            raise KeyError(f"expert {e} is not in this rank's shard")
        self.lookups += 1
        if e in self._lru:
            self.hits += 1
            self._lru.remove(e)
            self._lru.append(e)           # most-recent position
            return True
        self.misses += 1
        return False

    def stage(self, e: int) -> Optional[int]:
        """Make ``e`` resident, evicting the LRU unpinned expert if full.

        Returns the evicted expert id, -1 if a free slot absorbed the
        stage, or None if the stage is impossible (every slot pinned) —
        the caller must not treat ``e`` as resident in that case.
        Staging an already-resident expert is a no-op refresh.
        """
        e = int(e)
        if e not in self.eligible:
            raise KeyError(f"expert {e} is not in this rank's shard")
        if e in self._lru:
            self._lru.remove(e)
            self._lru.append(e)
            return -1
        evicted = -1
        if len(self._lru) >= self.capacity:
            victim = next((v for v in self._lru if v not in self._pinned),
                          None)
            if victim is None:
                return None               # all pinned: cannot make room
            self._lru.remove(victim)
            self.evictions += 1
            evicted = victim
        self._lru.append(e)
        self.stages += 1
        return evicted

    def evict(self, e: int) -> bool:
        """Explicitly drop ``e``; False if pinned or not resident."""
        e = int(e)
        if e in self._pinned or e not in self._lru:
            return False
        self._lru.remove(e)
        self.evictions += 1
        return True

    def pin(self, experts: Sequence[int]) -> None:
        """Pin the current layer's working experts against eviction."""
        self._pinned = {int(e) for e in experts} & self.eligible

    def unpin(self) -> None:
        self._pinned = set()

    @property
    def pinned(self) -> frozenset:
        return frozenset(self._pinned)


@dataclasses.dataclass(frozen=True)
class ResidencyDecision:
    """One step's residency update (applied double-buffered: the engine
    dispatches the staging scatter for step t's decision at the *start*
    of step t+1, so the jitted copy overlaps step t+1's compute)."""
    residency_ids: np.ndarray   # [G, W] int32 resident expert ids per rank
    stage_rows: np.ndarray      # [n_staged] int32 stacked weight-row indices
    changed: bool               # False => table identical to the previous one
    hits: int
    misses: int
    prefetches: int             # predictive stages ahead of first touch
    stall_units: float          # modeled serialized-transfer seconds
    bytes_staged: float


class ExpertResidencyManager:
    """Per-rank tiered residency driven by per-layer router load.

    Parameters
    ----------
    topo:
        Serving expert-parallel topology. Requires ``hosts_per_expert == 1``
        (same constraint as replication: each expert has one host rank).
    resident_experts:
        Pod-total HBM working-set budget; must divide evenly into
        ``W = resident_experts / G`` slots per rank, ``1 <= W <= epr``.
        ``resident_experts == padded_experts`` means everything fits
        (fully resident — the differential-test baseline).
    policy:
        ``predictive`` | ``on_demand`` | ``none`` (see module docstring).
    cost:
        Tier cost model; the engine fills ``expert_bytes`` from the real
        parameter leaves.
    ema_alpha:
        Per-layer EMA smoothing weight (same default as ``ExpertRebalancer``).
    """

    def __init__(self, topo: EPTopology, resident_experts: int, *,
                 policy: str = "predictive",
                 cost: Optional[TierCostModel] = None,
                 ema_alpha: float = 0.2):
        if policy not in PREFETCH_POLICIES:
            raise ValueError(
                f"prefetch_policy must be one of {PREFETCH_POLICIES}, "
                f"got {policy!r}")
        if topo.hosts_per_expert != 1:
            raise ValueError(
                "tiered expert residency requires E >= num_ranks "
                "(each expert having a unique host)")
        G, epr = topo.num_ranks, topo.experts_per_rank
        if resident_experts <= 0 or resident_experts % G != 0:
            raise ValueError(
                f"resident_experts={resident_experts} must be a positive "
                f"multiple of the EP degree {G}")
        W = resident_experts // G
        if W > epr:
            raise ValueError(
                f"resident_experts={resident_experts} exceeds the pod's "
                f"{G * epr} expert rows ({W} slots/rank > {epr}/rank)")
        self.topo = topo
        self.W = W
        self.policy = policy
        self.cost = cost if cost is not None else TierCostModel()
        self.ema_alpha = float(ema_alpha)
        self._lsl = local_slot_of(topo)                      # [G, Ep]
        # per-layer EMA of the [Ep] expert-load diagnostic (PR-6 follow-on)
        self.layer_ema: Dict[int, np.ndarray] = {}
        self.steps_observed = 0
        # one pinned-LRU cache per rank over its own shard; seed the
        # working set with the first W local slots so step 0 is defined
        self.caches = [ResidencyCache(W, topo.slot_map[g])
                       for g in range(G)]
        for g in range(G):
            for j in range(W):
                self.caches[g].stage(int(topo.slot_map[g, j]))
        self._last_ids = self._table()
        # lifetime counters (metrics window reads + resets via counters())
        self._win = dict(hits=0, misses=0, lookups=0, swaps=0,
                         prefetches=0, stall_units=0.0, bytes_staged=0.0)

    # ------------------------------------------------------------- helpers
    @property
    def fully_resident(self) -> bool:
        return self.W == self.topo.experts_per_rank

    def _table(self) -> np.ndarray:
        """[G, W] residency table: resident expert ids, -1 pads.

        Sorted per rank: the device side only tests membership, so a
        recency-order permutation must not read as a table change (the
        ``none`` policy's table stays literally frozen)."""
        G = self.topo.num_ranks
        ids = np.full((G, self.W), -1, np.int32)
        for g in range(G):
            res = sorted(self.caches[g].resident)
            ids[g, :len(res)] = res
        return ids

    def _row(self, g: int, e: int) -> int:
        return g * self.topo.experts_per_rank + int(self._lsl[g, e])

    def observe(self, layer_loads: np.ndarray) -> None:
        """Fold one step's [L, Ep] per-layer expert loads into the EMAs."""
        loads = np.asarray(layer_loads, np.float64)
        if loads.ndim != 2 or loads.shape[1] != self.topo.padded_experts:
            raise ValueError(
                f"layer_loads must be [n_moe_layers, {self.topo.padded_experts}]"
                f", got {loads.shape}")
        a = self.ema_alpha
        for layer in range(loads.shape[0]):
            prev = self.layer_ema.get(layer)
            self.layer_ema[layer] = loads[layer].copy() if prev is None \
                else (1.0 - a) * prev + a * loads[layer]
        self.steps_observed += 1

    def _predict(self, layer: int, g: int) -> List[int]:
        """Top-W local experts the EMA expects layer ``layer`` to use."""
        ema = self.layer_ema.get(layer)
        if ema is None:
            return []
        local = self.topo.slot_map[g]
        order = np.argsort(-ema[local], kind="stable")
        return [int(local[j]) for j in order if ema[local[j]] > 0.0][: self.W]

    # ---------------------------------------------------------------- step
    def step(self, layer_loads: np.ndarray) -> ResidencyDecision:
        """Replay one engine step's per-layer loads through the caches.

        Folds the loads into the per-layer EMA, then walks the layers in
        execution order: experts the router sent tokens to are looked up
        (pinning them for the layer), demand misses are staged (stalling
        under ``on_demand``/unpredicted ``predictive``; never staged
        under ``none``), and — under ``predictive`` — the *next* layer's
        EMA-top experts are prefetched during this layer's compute
        window, hiding their transfer behind the modeled overlap.
        """
        loads = np.asarray(layer_loads, np.float64)
        self.observe(loads)
        G = self.topo.num_ranks
        n_layers = loads.shape[0]
        hits = misses = prefetches = 0
        stall = bytes_staged = 0.0
        stage_rows: List[int] = []
        prefetched: List[set] = [set() for _ in range(G)]
        for layer in range(n_layers):
            for g in range(G):
                cache = self.caches[g]
                local = self.topo.slot_map[g]
                used = [int(e) for e in local if loads[layer, e] > 0.0]
                cache.pin(used)
                for e in used:
                    if cache.lookup(e):
                        hits += 1
                        continue
                    misses += 1
                    if self.policy == "none":
                        # frozen working set: pay the tier cost every use
                        stall += self.cost.stall_units(1)
                        continue
                    if cache.stage(e) is None:
                        stall += self.cost.stall_units(1)
                        continue          # all slots pinned: serve from host
                    bytes_staged += self.cost.expert_bytes
                    self._win["swaps"] += 1
                    stage_rows.append(self._row(g, e))
                    if e in prefetched[g]:
                        prefetched[g].discard(e)   # double-counted stage
                    else:
                        stall += self.cost.stall_units(1)
                # predictive: stage next layer's predicted experts now —
                # the transfer overlaps this layer's compute, so a correct
                # prediction turns a stall into hidden bytes
                if self.policy == "predictive" and layer + 1 < n_layers:
                    for e in self._predict(layer + 1, g):
                        if e in cache:
                            continue
                        if cache.stage(e) is None:
                            continue      # pinned-full: skip the prefetch
                        prefetches += 1
                        bytes_staged += self.cost.expert_bytes
                        self._win["swaps"] += 1
                        stage_rows.append(self._row(g, e))
                        prefetched[g].add(e)
                cache.unpin()
        ids = self._table()
        changed = not np.array_equal(ids, self._last_ids)
        self._last_ids = ids.copy()
        self._win["hits"] += hits
        self._win["misses"] += misses
        self._win["lookups"] += hits + misses
        self._win["prefetches"] += prefetches
        self._win["stall_units"] += stall
        self._win["bytes_staged"] += bytes_staged
        return ResidencyDecision(
            residency_ids=ids,
            stage_rows=np.asarray(sorted(set(stage_rows)), np.int32),
            changed=changed, hits=hits, misses=misses,
            prefetches=prefetches, stall_units=stall,
            bytes_staged=bytes_staged)

    # ------------------------------------------------------------- metrics
    def counters(self) -> Dict[str, float]:
        """Lifetime residency counters for ``report()["residency"]``."""
        w = dict(self._win)
        w["hit_rate"] = (w["hits"] / w["lookups"]) if w["lookups"] else None
        return w
