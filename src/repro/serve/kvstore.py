"""KV-ownership component of the serving engine.

``KVOwner`` owns everything about *where KV lives*: the physical pool
(slab rows or paged blocks), the block allocator + prefix index, the
batch-1 prefill scratch, and the jitted device plumbing that moves KV
between them (``write_chunk_blocks`` / ``write_slot`` /
``gather_prefix_blocks`` / ``copy_block``).  The engine keeps scheduling
state (slots, positions, the decode batch) and delegates every
pool/allocator touch here — which is what lets an engine run as a
``prefill``-only or ``decode``-only *role*: the prefill role exports a
finished request's KV as a :class:`HandoffRecord` and the decode role
imports it into its own pool, token-exactly, through the same
``write_chunk_blocks`` scatter ordinary prefill uses.

Handoff format: the record carries each scratch cache leaf's first
``pad_len`` KV positions (seq axis moved to the front, so the arrays are
``[pad_len, ...]`` regardless of the leaf's native layout) in
``jax.tree.leaves`` order, plus the token-level request state (prompt,
committed outputs, timestamps).  Plain numpy + ints — picklable, and
``to_npz_bytes``/``from_npz_bytes`` give an explicit wire form.  Import
rebuilds a batch-1 scratch from the record and scatters it chunk-by-chunk
through the importing engine's own block table, so the destination pool's
K/V is bit-identical to what a unified engine would have prefilled.
"""
from __future__ import annotations

import io
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import round_up
from repro.serve.paging import (NULL_BLOCK, BlockAllocator, blocks_for_tokens,
                                copy_block, gather_prefix_blocks,
                                write_chunk_blocks)
from repro.serve.slots import (discover_batch_axes, discover_seq_axes,
                               min_kv_capacity, write_slot)


@dataclass
class HandoffRecord:
    """Serializable prefill→decode handoff: one finished prefill's block
    chain contents + committed-prefix state.

    ``kv`` holds each scratch leaf's positions ``[0, pad_len)`` with the
    KV-length axis moved to the front (``jax.tree.leaves`` order of the
    cache pytree); ``pad_len`` is the chunk-rounded committed length, so
    the importer can replay the exact ``write_chunk_blocks`` scatters the
    unified engine would have issued.  ``output`` carries every committed
    token (the prefill role hands off with exactly one — the first token
    its final chunk sampled); timestamps ride along so the decode side's
    completion record keeps the true TTFT.
    """
    rid: int
    prompt_tokens: np.ndarray
    output: List[int]
    pos: int                       # committed KV length (prefill_len)
    pad_len: int                   # chunk-rounded slice length of ``kv``
    prefill_chunk: int             # chunk size the exporter prefilled with
    max_new_tokens: int
    eos_id: Optional[int]
    kv: List[np.ndarray] = field(default_factory=list)
    cached_prefix_tokens: int = 0
    arrival_time: float = 0.0
    admitted_time: float = 0.0
    first_token_time: float = 0.0

    @property
    def nbytes(self) -> int:
        """Wire size of the KV payload (the dominant handoff cost)."""
        return int(sum(a.nbytes for a in self.kv)) \
            + self.prompt_tokens.nbytes + 4 * len(self.output)

    def to_npz_bytes(self) -> bytes:
        """Explicit wire form: one npz blob.  KV leaves travel as raw
        bytes plus sidecar dtype/shape arrays — npz's own dtype headers
        cannot describe ml_dtypes extension types like bfloat16."""
        buf = io.BytesIO()
        header = np.asarray([self.rid, self.pos, self.pad_len,
                             self.prefill_chunk, self.max_new_tokens,
                             -1 if self.eos_id is None else self.eos_id,
                             self.cached_prefix_tokens], np.int64)
        times = np.asarray([self.arrival_time, self.admitted_time,
                            self.first_token_time], np.float64)
        payload = {f"kv_{i}": np.frombuffer(a.tobytes(), np.uint8)
                   for i, a in enumerate(self.kv)}
        np.savez(buf, header=header, times=times,
                 prompt=self.prompt_tokens,
                 output=np.asarray(self.output, np.int64),
                 kv_dtypes=np.asarray([str(a.dtype) for a in self.kv]),
                 kv_shapes=np.asarray([",".join(map(str, a.shape))
                                       for a in self.kv]),
                 **payload)
        return buf.getvalue()

    @classmethod
    def from_npz_bytes(cls, blob: bytes) -> "HandoffRecord":
        z = np.load(io.BytesIO(blob))
        h = z["header"]
        kv = []
        for i, (dt, shp) in enumerate(zip(z["kv_dtypes"], z["kv_shapes"])):
            shape = tuple(int(s) for s in str(shp).split(",") if s)
            kv.append(np.frombuffer(z[f"kv_{i}"].tobytes(),
                                    np.dtype(str(dt))).reshape(shape))
        return cls(rid=int(h[0]), prompt_tokens=z["prompt"].astype(np.int32),
                   output=[int(t) for t in z["output"]], pos=int(h[1]),
                   pad_len=int(h[2]), prefill_chunk=int(h[3]),
                   max_new_tokens=int(h[4]),
                   eos_id=None if int(h[5]) < 0 else int(h[5]),
                   kv=kv, cached_prefix_tokens=int(h[6]),
                   arrival_time=float(z["times"][0]),
                   admitted_time=float(z["times"][1]),
                   first_token_time=float(z["times"][2]))


class KVOwner:
    """Paged-or-slab KV pool + allocator + jitted KV movement: the
    token-indexed implementation of ``statestore.SequenceStateStore``.

    Construction mirrors what ``ServeEngine.__init__`` used to inline:
    structural axis discovery, pool/scratch init (under the engine's mesh
    context), and one jitted entry per movement primitive.  ``pool`` and
    ``scratch`` are plain mutable attributes the engine's step loop
    reassigns; the allocator and block table are owned here.

    Sliding-window models are served paged as **ring buffers**: the pool
    and scratch are built over the *unclamped* cache
    (``init_cache(..., clamp_window=False)`` — chunked prefill attends
    through the full-length scratch, where the window is enforced by the
    attention mask), and each window-clamped leaf gets a per-leaf ring
    modulus ``M = round_up(window, block_size)`` (``ring_mods``): logical
    position ``p`` lives at ring slot ``p % M`` of the slot's chain, both
    in the prefill scatter (``write_chunk_blocks``) and the decode
    write/gather (``paged_ring_decode_attention``).  When *every* KV leaf
    is windowed the chain itself shrinks to ``M / block_size`` blocks —
    fixed-size per slot, allocated whole at admission
    (``ring_full_chain``) — which is where the paged pool's memory win
    over the slab comes from for long-context windowed serving.
    """

    def __init__(self, model, ecfg, *, s_pad: int, ctx: Callable[[], Any]):
        self.ecfg = ecfg
        self.paged = ecfg.paged
        self.sharing = ecfg.prefix_sharing
        self._ctx = ctx
        B, C = ecfg.max_slots, ecfg.prefill_chunk
        self.seq_axes = discover_seq_axes(model.init_cache, ecfg.max_seq_len)
        self.alloc: Optional[BlockAllocator] = None
        self.block_table: Optional[np.ndarray] = None
        self.gather_fn = None
        self.copy_fn = None
        self.ring = False
        self.ring_full_chain = False
        self.ring_mod = 0
        if self.paged:
            bs = ecfg.kv_block_size
            if bs < 1:
                raise ValueError("kv_block_size must be >= 1")
            self.s_pad = s_pad
            self.blocks_per_slot = blocks_for_tokens(s_pad, bs)
            # ring discovery: a leaf is windowed iff clamping changes its
            # KV length at s_pad.  Windowed leaves wrap positions modulo
            # M; with every leaf windowed the whole chain shrinks to M.
            window = model.cfg.sliding_window or 0
            M = round_up(window, bs) if window else 0
            clamped = jax.eval_shape(lambda: model.init_cache(1, s_pad))
            full = jax.eval_shape(
                lambda: model.init_cache(1, s_pad, False))
            self.ring_mods = jax.tree.map(
                lambda c, f, ax: (M if ax >= 0
                                  and c.shape[ax] != f.shape[ax] else 0),
                clamped, full, self.seq_axes)
            n_seq = sum(1 for a in jax.tree.leaves(self.seq_axes) if a >= 0)
            n_ring = sum(1 for m in jax.tree.leaves(self.ring_mods) if m)
            self.ring = n_ring > 0
            self.ring_mod = M if self.ring else 0
            self.ring_full_chain = self.ring and n_ring == n_seq
            if self.ring_full_chain:
                # every leaf wraps: a chain of M/bs blocks serves any
                # logical length — fixed-size per slot, like an SSM slot
                self.blocks_per_slot = M // bs
            usable = ecfg.num_kv_blocks or B * self.blocks_per_slot
            if usable < self.blocks_per_slot:
                raise ValueError(
                    f"num_kv_blocks={usable} cannot hold even one "
                    f"worst-case request ({self.blocks_per_slot} blocks)")
            self.alloc = BlockAllocator(usable + 1, bs,   # +1: null block
                                        prefix_cache=self.sharing)
            self.block_table = np.full((B, self.blocks_per_slot),
                                       NULL_BLOCK, np.int32)
            self.kv_capacity = s_pad
            with self._ctx():
                # the pool/scratch are built over the unclamped cache
                # (assert_pageable validates full KV axes at s_pad; the
                # window is enforced by ring_mods + the attention mask,
                # never by silent truncation)
                self.pool = model.init_paged_cache(
                    self.alloc.num_blocks, bs, s_pad,
                    seq_axes=self.seq_axes, clamp_window=False)
                self.scratch = model.init_cache(1, s_pad, False)
            ring_mods = self.ring_mods if self.ring else None
            self.write_fn = jax.jit(
                lambda pool, scratch, bt_row, start, valid_to:
                write_chunk_blocks(
                    pool, scratch, bt_row, start, chunk=C, block_size=bs,
                    seq_axes=self.seq_axes, ring_mods=ring_mods,
                    valid_to=valid_to))
            if self.sharing:
                self.gather_fn = jax.jit(
                    lambda pool, scratch, bt_row, n: gather_prefix_blocks(
                        pool, scratch, bt_row, n, s_pad=s_pad,
                        block_size=bs, seq_axes=self.seq_axes))
                self.copy_fn = jax.jit(
                    lambda pool, src, dst: copy_block(
                        pool, src, dst, block_size=bs,
                        seq_axes=self.seq_axes))
        else:
            self.s_pad = ecfg.max_seq_len
            self.blocks_per_slot = 0
            self.batch_axes = discover_batch_axes(model.init_cache,
                                                  ecfg.max_seq_len)
            self.kv_capacity = min_kv_capacity(
                model.init_cache, ecfg.max_seq_len, self.seq_axes)
            with self._ctx():
                self.pool = model.init_cache(B, ecfg.max_seq_len)
                self.scratch = model.init_cache(1, ecfg.max_seq_len)
            self.write_fn = jax.jit(
                lambda pool, scratch, slot: write_slot(pool, scratch, slot,
                                                       self.batch_axes))

    # ------------------------------------------------------------------
    # SequenceStateStore protocol (serve/statestore.py)
    # ------------------------------------------------------------------
    def begin_prefill(self) -> None:
        """Token-indexed scratch needs no reset: stale positions sit past
        ``cache_len`` and are dead by masking."""

    def release(self, rid: int, slot: int) -> None:
        """Free request ``rid``'s blocks and park its table row on the
        null block (no-op for the slab: its row is overwritten whole at
        the next admission)."""
        if self.paged:
            self.alloc.release(rid)
            self.block_table[slot, :] = NULL_BLOCK

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "kind": "paged" if self.paged else "slab",
        }
        if self.paged:
            out["kv_block_size"] = self.ecfg.kv_block_size
            out["blocks_per_slot"] = self.blocks_per_slot
            out["usable_blocks"] = self.alloc.usable_blocks
            out["blocks_in_use"] = self.alloc.blocks_in_use
            out["window_ring"] = self.ring
            if self.ring:
                out["ring_tokens"] = self.ring_mod
                out["ring_full_chain"] = self.ring_full_chain
        else:
            out["slots"] = self.ecfg.max_slots
        return out

    # ------------------------------------------------------------------
    # admission planning (block math; the engine owns slot scheduling)
    # ------------------------------------------------------------------
    def share_plan(self, tokens, resumed: bool) -> Tuple[int, List[int],
                                                         int, bool]:
        """Admission plan for a (re)prefill over ``tokens``:
        ``(start_pf, shared_blocks, n_fresh, cow_last)``.

        ``shared_blocks`` is the longest indexed prefix at block
        granularity (empty without prefix sharing) and ``start_pf`` the
        offset prefill resumes from — normally the end of the shared
        prefix.  On a *full*-sequence hit a fresh request still needs the
        last position's logits, so it restarts at ``len - 1``; that write
        lands inside the last shared block, which must be CoW'd first
        (``cow_last``).  A resumed request needs no logits (its pending
        last token is already committed), so a full hit skips prefill
        entirely.  ``n_fresh`` counts the fresh tail blocks covering the
        chunk-padded prefill writes."""
        C, bs = self.ecfg.prefill_chunk, self.ecfg.kv_block_size
        L = len(tokens)
        if self.ring_full_chain:
            # every KV leaf wraps the same fixed ring: a slot's chain is
            # whole-or-nothing, allocated up front regardless of prompt
            # length (sharing is rejected for windowed models — a ring
            # slot's contents depend on the sequence's absolute length)
            return 0, [], self.blocks_per_slot, False
        shared = self.alloc.match_prefix(tokens) if self.sharing else []
        P = len(shared) * bs
        cow_last = False
        if P >= L:                         # full hit (only when L % bs == 0)
            start = L if resumed else L - 1
            cow_last = not resumed
        else:
            start = P
        cover = start + (round_up(L - start, C) if L > start else 0)
        n_fresh = max(blocks_for_tokens(cover, bs), len(shared)) \
            - len(shared)
        return start, shared, n_fresh, cow_last

    def can_admit(self, plan) -> bool:
        start, shared, n_fresh, cow_last = plan
        return self.alloc.can_allocate(n_fresh + int(cow_last), shared)

    def bt_row(self, rid: int) -> np.ndarray:
        """A request's block-table row, built from its live chain (the
        engine-visible table row may still be parked on the null block)."""
        row = np.full((self.blocks_per_slot,), NULL_BLOCK, np.int32)
        chain = self.alloc.chain(rid)
        row[:len(chain)] = chain
        return row

    def probe_prefix(self, tokens) -> int:
        """Longest cached-prefix match in *tokens* (router affinity probe):
        a pure lookup that leaves the LRU ordering untouched, so probing a
        replica that is not chosen never perturbs its eviction order."""
        if not self.sharing:
            return 0
        return len(self.alloc.match_prefix(tokens, touch=False)) \
            * self.ecfg.kv_block_size

    # ------------------------------------------------------------------
    # prefill→decode handoff (paged only; see HandoffRecord)
    # ------------------------------------------------------------------
    def export_kv(self, pad_len: int) -> List[np.ndarray]:
        """Slice the scratch cache's positions ``[0, pad_len)`` out to
        host numpy, seq axis first — after a finished chunked prefill the
        scratch holds the request's full committed K/V (a gathered cached
        prefix included), so this IS the handoff payload."""
        axes = jax.tree.leaves(self.seq_axes)
        leaves = jax.tree.leaves(self.scratch)
        return [np.ascontiguousarray(
                    np.moveaxis(np.asarray(leaf), ax, 0)[:pad_len])
                for leaf, ax in zip(leaves, axes)]

    def import_kv(self, kv_leaves: List[np.ndarray], pad_len: int,
                  bt_row: np.ndarray) -> None:
        """Scatter a handoff record's KV into this pool through ``bt_row``
        using the same jitted ``write_chunk_blocks`` entry ordinary
        prefill uses (chunk by chunk over ``[0, pad_len)``), via a
        temporary batch-1 scratch rebuilt from the record.  Token-exact:
        the written K/V is bit-identical to the exporter's."""
        C = self.ecfg.prefill_chunk
        axes = jax.tree.leaves(self.seq_axes)
        leaves, treedef = jax.tree.flatten(self.scratch)
        if len(kv_leaves) != len(leaves):
            raise ValueError(
                f"handoff record has {len(kv_leaves)} KV leaves; this "
                f"engine's cache has {len(leaves)} — the two roles must "
                f"serve the same model")
        rebuilt = []
        for leaf, ax, rec in zip(leaves, axes, kv_leaves):
            shp = list(leaf.shape)
            seq_len = shp.pop(ax)
            want = (pad_len, *shp)
            if pad_len > seq_len or tuple(rec.shape) != want:
                raise ValueError(
                    f"handoff KV leaf shape {tuple(rec.shape)} does not "
                    f"match this engine's cache slice {want} "
                    f"(leaf {tuple(leaf.shape)}, seq axis {ax})")
            arr = np.zeros((seq_len, *shp), rec.dtype)
            arr[:pad_len] = rec
            # place under the live scratch's sharding: on a multi-device
            # mesh a default-placed (replicated) array would miss the
            # write_fn entry warmup compiled against sharded scratch
            rebuilt.append(jax.device_put(np.moveaxis(arr, 0, ax),
                                          leaf.sharding))
        imp = jax.tree.unflatten(treedef, rebuilt)
        with self._ctx():
            for start in range(0, pad_len, C):
                self.pool = self.write_fn(self.pool, imp, bt_row,
                                          np.int32(start),
                                          np.int32(pad_len))

    # ------------------------------------------------------------------
    def jit_counts(self) -> Dict[str, int]:
        counts = {("write_blocks" if self.paged else "write_slot"):
                  self.write_fn._cache_size()}
        if self.paged and self.sharing:
            counts["gather_prefix"] = self.gather_fn._cache_size()
            counts["copy_block"] = self.copy_fn._cache_size()
        return counts
