"""Sequence-state ownership: the protocol every serving state pool obeys.

The engine used to talk to "a KV pool".  That framing breaks the moment a
model's per-sequence state is not a growing token-indexed cache: an SSM
layer (``models/mamba2.py``) carries a *fixed-size* recurrent state per
sequence — one [H, P, N] SSD state plus [W-1, ...] causal-conv tails —
and a hybrid (zamba2-style) stack carries both kinds at once.  What the
engine actually needs is a **state-ownership API**:

* ``SequenceStateStore`` — the protocol (admit planning, allocation,
  write, free/preempt, export).  ``ServeEngine``/``frontend``/``stepcore``
  address per-sequence state only through this surface.
* ``kvstore.KVOwner`` — the token-indexed implementation (slab rows or
  paged blocks + allocator + prefix index + handoff).
* ``SlotStateStore`` (here) — the slotted, preemptible state pool for
  SSM and hybrid models: ``model.init_cache(max_slots, max_seq_len)``
  reinterpreted as one slab whose rows hold *whatever state the model
  declares* — fixed-size conv + SSD recurrent state for SSM leaves,
  window-clamped ring-buffer K/V for hybrid attention leaves — composed
  in one pytree, written by the same traced-slot ``write_slot`` scatter.

Recurrent state makes two things first-class that the KV slab never
needed:

* **Prefill-continuation carry** — chunked prefill folds every consumed
  token into the batch-1 scratch *state* (there is no ``cache_len`` mask
  to hide stale positions behind), so the scratch must be reset to the
  pristine zero state each time a *new* request starts prefilling.
  ``begin_prefill()`` is that hook; for ``KVOwner`` it is a no-op (stale
  scratch positions are dead by masking).  Pad tokens inside the final
  chunk are masked out of the state update itself (``dt = 0`` at pad
  positions is an exact SSD identity; the conv tails are sliced at the
  last valid input) — see ``mamba_block(valid_len=...)``.
* **Token-exact preemption resume** — a preempted request's slot state is
  simply dropped; resume re-prefills prompt + committed output through
  the same chunked path and rewrites the slot, which reproduces the
  recurrent state exactly (state is a pure fold over the token stream).

Admission is slot-gated (the state is worst-case-sized per slot, so a
free slot is the only resource); ``share_plan`` degenerates to "start at
0, no shared blocks".  Cross-engine handoff of recurrent state is not
wired (split prefill/decode roles stay paged-transformer-only).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import jax
import numpy as np

from repro.serve.slots import (discover_batch_axes, discover_seq_axes,
                               min_kv_capacity, write_slot)

AdmitPlan = Tuple[int, List[int], int, bool]


class SequenceStateStore(Protocol):
    """What ``ServeEngine`` asks of the component that owns per-sequence
    model state.  Implementations: ``kvstore.KVOwner`` (token-indexed K/V,
    slab or paged) and ``SlotStateStore`` (slotted SSM/hybrid state).

    Mutable attributes the engine's step loop reassigns:

    * ``pool`` — the full-batch state pytree every decode step threads;
    * ``scratch`` — the batch-1 prefill state.

    Static attributes fixed at construction: ``paged``, ``sharing``,
    ``s_pad`` (scratch KV length), ``kv_capacity`` (longest admissible
    padded prompt), ``blocks_per_slot``/``block_table``/``alloc`` (paged
    bookkeeping; 0/None for slotted stores), ``write_fn`` (the jitted
    scratch→pool commit), ``gather_fn``/``copy_fn`` (prefix sharing only).
    """
    paged: bool
    sharing: bool
    pool: Any
    scratch: Any
    s_pad: int
    kv_capacity: int
    blocks_per_slot: int
    block_table: Optional[np.ndarray]
    alloc: Any
    write_fn: Any
    gather_fn: Any
    copy_fn: Any

    def begin_prefill(self) -> None:
        """A new request is about to start prefilling into the scratch.
        Stores with recurrent scratch state reset it to the pristine zero
        state here; token-indexed stores need nothing (stale positions
        are dead by ``cache_len`` masking)."""
        ...

    def share_plan(self, tokens, resumed: bool) -> AdmitPlan:
        """Admission plan ``(start, shared_blocks, n_fresh, cow_last)``
        for a (re)prefill over ``tokens``."""
        ...

    def can_admit(self, plan: AdmitPlan) -> bool:
        """Whether the store can allocate ``plan`` right now."""
        ...

    def release(self, rid: int, slot: int) -> None:
        """Free every store-side resource request ``rid`` in ``slot``
        holds (finish and preempt both land here).  Slot recycling itself
        belongs to the engine's front."""
        ...

    def bt_row(self, rid: int) -> np.ndarray:
        """The request's block-table row (paged stores only)."""
        ...

    def probe_prefix(self, tokens) -> int:
        """Longest cached-prefix match in tokens (0 without sharing)."""
        ...

    def export_kv(self, pad_len: int) -> List[np.ndarray]:
        """Slice the scratch state for a prefill→decode handoff."""
        ...

    def import_kv(self, kv_leaves: List[np.ndarray], pad_len: int,
                  bt_row: np.ndarray) -> None:
        """Scatter a handoff record's state into this pool."""
        ...

    def stats(self) -> Dict[str, Any]:
        """The ``state_pool`` report section: pool kind, per-slot bytes,
        and store-specific counters (see serve/README.md)."""
        ...

    def jit_counts(self) -> Dict[str, int]:
        """Jit cache sizes of every store-owned entry (compile audit)."""
        ...


def _tree_nbytes(tree) -> int:
    return int(sum(np.dtype(leaf.dtype).itemsize * int(np.prod(leaf.shape))
                   for leaf in jax.tree.leaves(tree)))


class SlotStateStore:
    """Slotted, preemptible state pool for SSM/hybrid models.

    ``pool`` is ``model.init_cache(max_slots, max_seq_len)`` — for a pure
    SSM stack that is per-slot *fixed-size* recurrent state (no KV-length
    axis at all); for a hybrid stack it composes the SSM leaves with the
    attention layers' (possibly window-clamped ring-buffer) K/V slabs in
    one pytree, so one engine serves both state kinds through one store.
    Prefill runs on the batch-1 ``scratch`` (reset to the pristine zero
    state at each ``begin_prefill`` — recurrent state carries across
    chunk calls, which is exactly what prefill continuation needs and
    exactly what a *new* request must not inherit) and the finished state
    is committed with the same traced-slot ``write_slot`` scatter the KV
    slab uses, so slot recycling never recompiles.

    Preemption is trivial by construction: dropping a slot loses nothing
    that ``prompt + committed output`` cannot rebuild, and resume
    re-prefills exactly that stream, making the recomputed state
    token-exact (the SSD update is a pure fold over tokens; pad positions
    are masked out of the fold itself — ``mamba_block(valid_len=...)``).
    """

    def __init__(self, model, ecfg, *, ctx: Callable[[], Any]):
        self.ecfg = ecfg
        self.paged = False
        self.sharing = False
        self._ctx = ctx
        # protocol surface the paged implementation populates
        self.alloc = None
        self.block_table = None
        self.gather_fn = None
        self.copy_fn = None
        self.blocks_per_slot = 0
        self.ring = False
        self.ring_full_chain = False
        self.ring_mod = 0
        B = ecfg.max_slots
        self.s_pad = ecfg.max_seq_len
        self.seq_axes = discover_seq_axes(model.init_cache, ecfg.max_seq_len)
        self.batch_axes = discover_batch_axes(model.init_cache,
                                              ecfg.max_seq_len)
        # pure SSM state has no KV-length axis anywhere: prompts are
        # bounded by max_seq_len alone.  Hybrid attention leaves (clamped
        # to a sliding window or not) reinstate the usual minimum.
        self.kv_capacity = min_kv_capacity(
            model.init_cache, ecfg.max_seq_len, self.seq_axes,
            default=ecfg.max_seq_len)
        with self._ctx():
            self.pool = model.init_cache(B, ecfg.max_seq_len)
            self.scratch = model.init_cache(1, ecfg.max_seq_len)
        # pristine zero state for begin_prefill resets: jax arrays are
        # immutable, so holding the initial scratch pytree (never fed back
        # through any jitted update) is a zero-copy template
        self._scratch0 = self.scratch
        self.write_fn = jax.jit(
            lambda pool, scratch, slot: write_slot(pool, scratch, slot,
                                                   self.batch_axes))
        self.scratch_resets = 0

    # ------------------------------------------------------------------
    def begin_prefill(self) -> None:
        self.scratch = self._scratch0
        self.scratch_resets += 1

    def share_plan(self, tokens, resumed: bool) -> AdmitPlan:
        return 0, [], 0, False

    def can_admit(self, plan: AdmitPlan) -> bool:
        return True               # slot-gated: the front checks free slots

    def release(self, rid: int, slot: int) -> None:
        pass                      # slot state is dropped, nothing to free

    def bt_row(self, rid: int) -> np.ndarray:
        raise RuntimeError("SlotStateStore has no block table")

    def probe_prefix(self, tokens) -> int:
        return 0

    def export_kv(self, pad_len: int) -> List[np.ndarray]:
        raise NotImplementedError(
            "recurrent-state handoff is not wired; split prefill/decode "
            "roles require the paged transformer KV store")

    def import_kv(self, kv_leaves, pad_len, bt_row) -> None:
        raise NotImplementedError(
            "recurrent-state handoff is not wired; split prefill/decode "
            "roles require the paged transformer KV store")

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        total = _tree_nbytes(self.pool)
        return {
            "kind": "slot",
            "slots": self.ecfg.max_slots,
            "state_bytes_per_slot": total // max(self.ecfg.max_slots, 1),
            "pool_bytes": total,
            "scratch_resets": self.scratch_resets,
        }

    def jit_counts(self) -> Dict[str, int]:
        return {"write_slot": self.write_fn._cache_size()}


def make_state_store(model, ecfg, *, s_pad: int, ctx: Callable[[], Any]):
    """Pick the state-store implementation for ``model``.

    SSM and hybrid families carry recurrent per-sequence state, which has
    no KV-length axis to address through a block table — they get the
    slotted pool (and reject ``paged=True`` loudly).  Everything else
    keeps ``KVOwner`` in whichever of its two modes ``ecfg`` selects.
    """
    from repro.serve.kvstore import KVOwner
    cfg = model.cfg
    if cfg.family in ("ssm", "hybrid"):
        if ecfg.paged:
            raise ValueError(
                f"{cfg.name} ({cfg.family}) carries fixed-size recurrent "
                f"state with no KV-length axis to page; serve it from the "
                f"slotted state pool (EngineConfig.paged=False)")
        return SlotStateStore(model, ecfg, ctx=ctx)
    return KVOwner(model, ecfg, s_pad=s_pad, ctx=ctx)
