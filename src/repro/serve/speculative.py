"""Self-drafting speculative decoding for the serve engine.

MoE decode is memory-bound: every step pays the full weight + KV traffic
to advance each sequence by one token.  Speculative decoding amortizes
that traffic by *verifying* up to ``k`` drafted tokens per step in one
static-shape forward over ``[B, k + 1]`` query positions against the
paged KV cache (``model.decode_step`` with a multi-token window, the
multi-query paged-attention kernel tiles), then committing the accepted
prefix plus one token from the verify logits — so a step commits between
1 and k + 1 tokens and is never slower than plain decode in tokens per
forward.

Two pluggable halves:

* **Drafting** (``DraftProposer``): where candidate tokens come from.
  The built-in ``NGramProposer`` is *self-drafting* (prompt-lookup
  decoding): the longest recent suffix n-gram of the request's context
  (prompt + committed output) is matched at its most recent earlier
  occurrence and the tokens that followed it are proposed.  No draft
  model, no extra forward — repetitive text (code, quoting, templated
  answers, greedy repetition loops) accepts long runs.  A small draft
  model can slot in later behind the same ``propose()`` contract.

* **Acceptance** (``greedy_verify`` / ``rejection_verify``): how many
  drafted tokens survive.  Greedy acceptance is exact-match against the
  verify argmax — the committed stream is token-identical to
  non-speculative greedy decode by construction.  At ``temperature > 0``
  the standard rejection-sampling rule (Leviathan et al.) runs against
  the *truncated* base distribution (``truncated_probs_np`` — the exact
  categorical ``sample_np`` draws from): the proposer is deterministic,
  a point mass q = 1 on the drafted token, so draft ``d`` is accepted
  with probability ``p(d)`` and a rejection resamples from the residual
  ``norm(p with d removed)`` = ``norm(max(p - q, 0))`` — the committed
  marginal at every position matches the base sampler's distribution
  exactly (distribution-tested in ``tests/test_serve_speculative.py``).

The engine half (KV bookkeeping, block growth/CoW over the speculative
write range, rollback-by-masking of rejected positions) lives in
``engine.py``; see the serve README "Speculative decoding".
"""
from __future__ import annotations

from typing import List, Protocol, Tuple

import numpy as np

from repro.serve.sampling import truncated_probs_np


class DraftProposer(Protocol):
    """Proposes up to ``k`` candidate continuation tokens for a context."""

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        """context: committed int32 token ids (prompt + output so far);
        returns at most ``k`` drafted next tokens (possibly empty — the
        verify step still commits one real token either way)."""
        ...


class NGramProposer:
    """Prompt-lookup / n-gram self-drafting.

    Finds the longest suffix n-gram of the context (between ``min_ngram``
    and ``max_ngram`` tokens) that re-occurs earlier in the context, and
    proposes the tokens that followed its most recent earlier occurrence.
    Deterministic, draft-model-free, O(len * max_ngram) per call on small
    serving contexts.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context, np.int32).reshape(-1)
        L = ctx.shape[0]
        if k < 1 or L < self.min_ngram + 1:
            return np.zeros((0,), np.int32)
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            suffix = ctx[L - n:]
            # most recent earlier occurrence: scan right-to-left over
            # window starts; the match must leave >= 1 token to propose
            for i in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[i:i + n], suffix):
                    return ctx[i + n:i + n + k].copy()
        return np.zeros((0,), np.int32)


_PROPOSERS = {"ngram": NGramProposer}


def make_proposer(policy: str, **kwargs) -> DraftProposer:
    """Build a draft proposer by policy name (``EngineConfig
    .speculative_policy``).  Extension point: register a class accepting
    the policy's kwargs and exposing ``propose(context, k)``."""
    try:
        cls = _PROPOSERS[policy]
    except KeyError:
        raise ValueError(
            f"unknown speculative_policy {policy!r}; "
            f"known: {sorted(_PROPOSERS)}") from None
    return cls(**kwargs)


# ----------------------------------------------------------------------
# Acceptance
# ----------------------------------------------------------------------
def greedy_verify(logits: np.ndarray, drafts: List[int]
                  ) -> Tuple[int, int]:
    """Greedy exact-match acceptance.

    ``logits``: [>= len(drafts) + 1, V] verify logits — row ``i`` scores
    the token following window position ``i`` (row 0 follows the committed
    last token, row i the i-th draft).  Drafts are accepted while they
    equal the argmax of the preceding row — exactly the token greedy
    decode would have emitted — and the first row after the accepted
    prefix contributes one committed token either way.  Returns
    ``(n_accepted, next_token)``."""
    n_acc = 0
    for d in drafts:
        if int(np.argmax(logits[n_acc])) != d:
            break
        n_acc += 1
    return n_acc, int(np.argmax(logits[n_acc]))


def rejection_verify(logits: np.ndarray, drafts: List[int],
                     rng: np.random.Generator, *, temperature: float,
                     top_k: int = 0, top_p: float = 1.0
                     ) -> Tuple[int, int]:
    """Rejection-sampling acceptance against the truncated base sampler.

    The self-drafting proposer is deterministic (q is a point mass on the
    drafted token), so draft ``d`` at position ``i`` is accepted with
    probability ``p_i(d)`` under the *truncated* base distribution, and a
    rejection draws the replacement from ``p_i`` with ``d`` removed and
    renormalized (= ``norm(max(p_i - q, 0))``).  Every committed token is
    therefore marginally distributed exactly as the base sampler's draw
    at that position.  After a fully accepted window the bonus token is a
    plain draw from the last row.  Returns ``(n_accepted, next_token)``.
    """
    n_acc = 0
    for d in drafts:
        ids, p = truncated_probs_np(logits[n_acc], temperature=temperature,
                                    top_k=top_k, top_p=top_p)
        at = np.nonzero(ids == d)[0]
        p_d = float(p[at[0]]) if at.size else 0.0
        if p_d >= 1.0 or rng.uniform() < p_d:
            n_acc += 1
            continue
        # rejected: resample from the residual (p with d zeroed); d had
        # p_d < 1 here, so at least one other candidate remains
        mask = ids != d
        resid = p[mask]
        resid = resid / resid.sum()
        return n_acc, int(ids[mask][rng.choice(resid.shape[0], p=resid)])
    ids, p = truncated_probs_np(logits[n_acc], temperature=temperature,
                                top_k=top_k, top_p=top_p)
    return n_acc, int(ids[rng.choice(p.shape[0], p=p)])
