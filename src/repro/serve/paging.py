"""Paged KV pool: block allocator + block-table plumbing for the engine.

The slab pool (``slots.py``) reserves ``max_seq_len`` KV positions per slot
for the whole lifetime of a request, so short requests strand memory and
``max_slots`` is capped by the worst case.  The paged pool decouples the
two: physical KV memory is a pool of fixed-size blocks (``kv_block_size``
tokens each), and every request owns a *chain* of blocks that grows as its
sequence does.  A static-shape block table ``[max_slots,
max_blocks_per_slot]`` maps each slot's logical block index to a physical
block id; attention gathers K/V through it (see
``repro.models.attention.paged_decode_attention``).

Physical block 0 is the *null block*: unallocated table entries point at it,
so gathers/scatters through a partially-filled table stay in bounds —
reads from it are masked by the per-row ``cache_len`` validity mask, writes
to it land in garbage that nothing reads.

Prefix sharing (``prefix_cache=True``) turns the allocator copy-on-write:
every block carries a refcount (number of chains it appears in), full
blocks are indexed in a radix tree keyed on their token-id chain, and a
new chain can adopt the longest indexed prefix of its token sequence with
refcount bumps instead of re-prefilling it.  Releasing a chain decrements
refcounts; indexed blocks that drop to refcount 0 are *retained* on an LRU
cached-free list — immediately reusable via a later prefix match, and
evicted (index entry dropped, block handed out) only when a fresh
allocation finds the plain free list dry.  A shared block is immutable;
``cow`` swaps a private copy into one chain so its owner can write.

Layout discovery is shared with the slab pool: ``discover_seq_axes`` finds
every cache leaf's KV-length axis structurally, and the same axis indices
drive the physical-pool construction, the chunk scatter, the prefix
gather, and the CoW block copy here — scan-stacked blocks and unscanned
lead layers need no special cases.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


NULL_BLOCK = 0      # physical block id unallocated table entries point at


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV positions."""
    return -(-n_tokens // block_size)


class _PrefixNode:
    """One radix-index node: a full block keyed by (parent node, the
    ``block_size`` token ids it holds).  The chain of keys from the root is
    exactly the token prefix whose K/V the block stores."""
    __slots__ = ("nid", "key", "block", "children")

    def __init__(self, nid: int, key: Tuple[int, Tuple[int, ...]],
                 block: int):
        self.nid = nid
        self.key = key          # (parent_nid, token tuple)
        self.block = block
        self.children: set = set()


_ROOT = 0               # nid of the (implicit) radix root


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` physical KV blocks.

    Block ids are dense ints; id 0 is reserved as the null block and never
    handed out.  Each request (keyed by rid) owns an ordered chain of
    blocks — logical block ``j`` of the request lives in physical block
    ``chain[j]``.

    With ``prefix_cache=True`` the allocator additionally keeps per-block
    refcounts, a radix prefix index over committed full blocks, and an LRU
    cached-free list of refcount-0 indexed blocks (see the module
    docstring).  Invariants (fuzzed by ``tests/test_paging_properties.py``):

    * conservation — ``free_blocks + blocks_in_use == usable_blocks``;
      every usable block is in exactly one of {free list, cached LRU,
      some chain(s)};
    * refcount consistency — a block appears in ``k`` chains iff its
      refcount is ``k`` (a block appears at most once per chain);
    * null immutability — ``NULL_BLOCK`` is never handed out, never in a
      chain, never indexed, never freed or evicted.
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 prefix_cache: bool = False):
        if num_blocks < 2:
            raise ValueError("need at least one usable block past the "
                             "reserved null block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = bool(prefix_cache)
        self._free: deque = deque(range(1, num_blocks))
        self._chains: Dict[int, List[int]] = {}
        self._ref: List[int] = [0] * num_blocks
        # refcount-0 blocks still holding indexed prefixes, LRU order
        # (oldest first = next eviction victim)
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        # radix prefix index
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], _PrefixNode] = {}
        self._by_nid: Dict[int, _PrefixNode] = {}
        self._by_block: Dict[int, _PrefixNode] = {}
        self._next_nid = _ROOT + 1
        # lifetime counters (the engine reports per-window deltas)
        self.evictions = 0
        self.cow_copies = 0

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        """Immediately allocatable blocks: the plain free list plus the
        cached LRU (evictable on demand)."""
        return len(self._free) + len(self._cached)

    @property
    def blocks_in_use(self) -> int:
        return self.usable_blocks - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    def chain(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._chains.get(rid, ()))

    def refcount(self, blk: int) -> int:
        return self._ref[blk]

    # ------------------------------------------------------------------
    # free-list / LRU internals
    # ------------------------------------------------------------------
    def _take_free(self) -> Optional[int]:
        """One allocatable block: plain free list first, then evict the
        LRU cached prefix block (dropping its index subtree)."""
        if self._free:
            return self._free.popleft()
        if self._cached:
            blk, _ = self._cached.popitem(last=False)
            node = self._by_block.get(blk)
            if node is not None:
                # blocks orphaned by an earlier subtree drop have no node
                # left and don't count as a prefix evicted again
                self._drop_subtree(node)
                self.evictions += 1
            return blk
        return None

    def _drop_subtree(self, node: _PrefixNode) -> None:
        """Remove ``node`` and every descendant from the index.  Descendant
        *blocks* are untouched (they may sit in chains or the cached LRU);
        only their index entries go — with their ancestor evicted they
        could never be reached by a prefix walk again."""
        parent = self._by_nid.get(node.key[0])
        if parent is not None:
            parent.children.discard(node.nid)
        stack = [node]
        while stack:
            n = stack.pop()
            stack.extend(self._by_nid[c] for c in n.children
                         if c in self._by_nid)
            del self._nodes[n.key]
            del self._by_nid[n.nid]
            if self._by_block.get(n.block) is n:
                del self._by_block[n.block]

    def _retire(self, blk: int) -> None:
        """A block's refcount just hit 0: retain it on the cached LRU if it
        still backs an index node, else return it to the free list."""
        if self.prefix_cache and blk in self._by_block:
            self._cached[blk] = None          # MRU end
        else:
            self._free.append(blk)

    # ------------------------------------------------------------------
    # chain lifecycle
    # ------------------------------------------------------------------
    def can_allocate(self, n_fresh: int, shared: Sequence[int] = ()) -> bool:
        """Would ``alloc_chain(rid, n_fresh, shared=shared)`` (plus
        ``n_fresh - len-of-tail`` CoW copies the caller folds in) succeed?
        Shared blocks currently parked on the cached LRU leave the free
        pool when mapped, so they reduce what's left for fresh blocks."""
        avail = self.free_blocks - sum(1 for b in shared if self._ref[b] == 0)
        return n_fresh <= avail

    def alloc_chain(self, rid: int, n_blocks: int,
                    shared: Sequence[int] = ()) -> Optional[List[int]]:
        """Install a chain for ``rid``: the ``shared`` prefix blocks (each
        refcount-bumped, revived from the cached LRU if parked there)
        followed by ``n_blocks`` fresh ones.  None (and no allocation) if
        the free pool cannot cover the fresh tail."""
        if rid in self._chains:
            raise ValueError(f"rid {rid} already holds a chain")
        if not self.can_allocate(n_blocks, shared):
            return None
        chain: List[int] = []
        for blk in shared:
            if blk == NULL_BLOCK:
                raise ValueError("cannot map the null block into a chain")
            if self._ref[blk] == 0:
                del self._cached[blk]         # revived from the LRU
            self._ref[blk] += 1
            chain.append(blk)
        for _ in range(n_blocks):
            blk = self._take_free()
            assert blk is not None            # guarded by can_allocate
            self._ref[blk] = 1
            chain.append(blk)
        self._chains[rid] = chain
        return list(chain)

    def extend(self, rid: int) -> Optional[int]:
        """Append one block to ``rid``'s chain; None if the pool is dry."""
        blk = self._take_free()
        if blk is None:
            return None
        self._ref[blk] = 1
        self._chains.setdefault(rid, []).append(blk)
        return blk

    def release(self, rid: int) -> int:
        """Drop ``rid``'s chain: every block's refcount is decremented and
        refcount-0 blocks return to the free pool — indexed ones onto the
        cached LRU (tail blocks first, so deep prefix blocks are evicted
        before the roots they hang off).  Returns #blocks whose refcount
        hit 0 (shared blocks still held by other chains stay in use)."""
        chain = self._chains.pop(rid, [])
        freed = 0
        for blk in reversed(chain):
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                self._retire(blk)
                freed += 1
        return freed

    # ------------------------------------------------------------------
    # prefix index
    # ------------------------------------------------------------------
    def _block_key(self, parent: int, tokens, j: int) -> Tuple[int, tuple]:
        bs = self.block_size
        return (parent, tuple(int(t) for t in tokens[j * bs:(j + 1) * bs]))

    def match_prefix(self, tokens, touch: bool = True) -> List[int]:
        """Physical blocks of the longest indexed prefix of ``tokens``, at
        block granularity.  Pure lookup — no refcounts change (map the
        result via ``alloc_chain(shared=...)``); matched cached blocks are
        touched to the LRU's MRU end.  ``touch=False`` skips the LRU
        touch: a fleet router probing every replica's index for prefix
        affinity must not perturb the eviction order of replicas it does
        not pick."""
        if not self.prefix_cache:
            return []
        out: List[int] = []
        parent = _ROOT
        for j in range(len(tokens) // self.block_size):
            node = self._nodes.get(self._block_key(parent, tokens, j))
            if node is None:
                break
            out.append(node.block)
            parent = node.nid
        # LRU touch tail-to-root so a prefix root always outlives its
        # descendants (evicting a root drops the whole subtree's entries)
        if touch:
            for blk in reversed(out):
                if blk in self._cached:
                    self._cached.move_to_end(blk)
        return out

    def commit_prefix(self, rid: int, tokens) -> int:
        """Index ``rid``'s chain blocks that hold full committed blocks of
        ``tokens`` (K/V for ``tokens[:k * block_size]`` must already be
        written).  Idempotent; first writer wins — a block whose key is
        already indexed (content-equal K/V elsewhere) is left unindexed and
        simply returns to the free list when its chain dies.  Returns the
        number of newly indexed blocks."""
        if not self.prefix_cache:
            return 0
        chain = self._chains.get(rid, [])
        parent = _ROOT
        new = 0
        for j in range(min(len(tokens) // self.block_size, len(chain))):
            key = self._block_key(parent, tokens, j)
            node = self._nodes.get(key)
            if node is None:
                blk = chain[j]
                if blk in self._by_block:
                    # already indexed under a different prefix — one block
                    # backs at most one node; stop the walk here
                    break
                node = _PrefixNode(self._next_nid, key, blk)
                self._next_nid += 1
                self._nodes[key] = node
                self._by_nid[node.nid] = node
                self._by_block[blk] = node
                p = self._by_nid.get(key[0])
                if p is not None:
                    p.children.add(node.nid)
                new += 1
            parent = node.nid
        return new

    # ------------------------------------------------------------------
    # copy-on-write
    # ------------------------------------------------------------------
    def cow(self, rid: int, j: int) -> Optional[Tuple[int, int]]:
        """Swap a private copy in for logical block ``j`` of ``rid``'s
        chain: a fresh block replaces it in the chain (refcount 1) and the
        original's refcount drops.  Returns ``(old, new)`` so the caller
        can perform the device copy, or None if the pool is dry (nothing
        changed).  Valid on shared *and* private blocks — CoW of a private
        indexed block detaches it from the index's content."""
        chain = self._chains.get(rid)
        if chain is None or not 0 <= j < len(chain):
            raise ValueError(f"rid {rid} has no logical block {j}")
        new = self._take_free()
        if new is None:
            return None
        old = chain[j]
        self._ref[new] = 1
        chain[j] = new
        self._ref[old] -= 1
        if self._ref[old] == 0:
            self._retire(old)
        self.cow_copies += 1
        return old, new


# ----------------------------------------------------------------------
# Physical pool construction
# ----------------------------------------------------------------------
def assert_pageable(init_cache: Callable[[int, int], Any], s_ref: int,
                    seq_axes: Any) -> None:
    """Every cache leaf must expose a full-length KV axis at ``s_ref``.

    Leaves clamped below ``s_ref`` or with no KV axis at all (SSM state)
    cannot be addressed through a block table — reject them up front with
    the offending shape.  Window-clamped attention leaves are served paged
    by building the pool over the *unclamped* cache
    (``init_cache(..., clamp_window=False)``) and wrapping logical
    positions into per-leaf rings (``ring_mods``); SSM state is served by
    the slotted ``serve.statestore.SlotStateStore`` instead.
    """
    shapes = jax.eval_shape(lambda: init_cache(1, s_ref))

    def check(leaf, ax):
        if ax < 0 or leaf.shape[ax] != s_ref:
            raise NotImplementedError(
                f"cache leaf {leaf.shape} is not pageable: its KV-length "
                f"axis is {'absent' if ax < 0 else 'clamped below'} "
                f"s_max={s_ref}; page window-clamped leaves via the "
                f"unclamped cache + ring_mods, and serve SSM state from "
                f"the slotted state pool (serve/statestore.py)")
    jax.tree.map(check, shapes, seq_axes)


def make_paged_pool(init_cache: Callable[[int, int], Any], s_ref: int,
                    seq_axes: Any, num_blocks: int, block_size: int) -> Any:
    """Physical paged pool: each cache leaf of ``init_cache(1, s_ref)`` with
    its KV-length axis resized to ``num_blocks * block_size`` positions.

    Built structurally (not via ``init_cache(1, P)``) so window-clamping
    inside ``init_cache`` can never silently truncate the physical pool.
    """
    assert_pageable(init_cache, s_ref, seq_axes)
    shapes = jax.eval_shape(lambda: init_cache(1, s_ref))
    P = num_blocks * block_size

    def build(leaf, ax):
        shape = list(leaf.shape)
        shape[ax] = P
        return jnp.zeros(tuple(shape), leaf.dtype)
    return jax.tree.map(build, shapes, seq_axes)


# ----------------------------------------------------------------------
# Chunk scatter: scratch -> allocated blocks
# ----------------------------------------------------------------------
def write_chunk_blocks(pool: Any, scratch: Any, bt_row: jnp.ndarray,
                       start: jnp.ndarray, *, chunk: int, block_size: int,
                       seq_axes: Any, ring_mods: Any = None,
                       valid_to: Optional[jnp.ndarray] = None) -> Any:
    """Scatter scratch positions ``[start, start + chunk)`` into the paged
    pool through one slot's block-table row.

    ``bt_row`` is the slot's ``[max_blocks_per_slot]`` int32 table row and
    ``start`` a traced int32 scalar (a chunk-aligned prefill offset), so one
    compilation serves every slot, chunk, and block assignment.  The chain
    behind ``bt_row`` must cover the whole chunk-rounded sequence (the
    engine allocates ``round_up(prefill_len, chunk)`` tokens of blocks at
    admission): pad positions past the prompt land in *real* allocated
    blocks, as garbage the validity mask keeps unread until decode
    overwrites it.  Only entries still parked on the null block (beyond the
    chain) write into discarded space.

    ``ring_mods`` (optional) is a per-leaf pytree of ring moduli: 0 for
    full-length leaves, M = round_up(window, block_size) for sliding-window
    leaves, whose logical position p lives at ring slot ``p % M`` of the
    chain.  The engine guarantees ``chunk <= M`` (validated at config
    build), so one chunk never self-overlaps a ring slot and the scatter
    stays order-independent.

    ``valid_to`` (traced int32 scalar; required with ``ring_mods``) is the
    logical end of *real* tokens in this chunk.  On a full-length leaf a
    pad position past it writes harmless garbage beyond ``cache_len`` that
    decode overwrites in place — but on a ring leaf that same logical
    position wraps onto the ring slot of a token still *inside* the
    window, so pad writes there are redirected into the null block (whose
    contents nothing ever reads) instead.
    """
    log = start + jnp.arange(chunk)

    def upd(p, sc, ax, mod):
        lg = (log % mod) if mod else log
        phys = bt_row[lg // block_size] * block_size + lg % block_size
        if mod and valid_to is not None:
            phys = jnp.where(log < valid_to, phys,
                             NULL_BLOCK * block_size + lg % block_size)
        pm = jnp.moveaxis(p, ax, 0)
        sm = jnp.moveaxis(sc, ax, 0)
        ck = jax.lax.dynamic_slice_in_dim(sm, start, chunk, axis=0)
        pm = pm.at[phys].set(ck.astype(pm.dtype))
        return jnp.moveaxis(pm, 0, ax)

    if ring_mods is None:
        ring_mods = jax.tree.map(lambda _: 0, seq_axes)
    return jax.tree.map(upd, pool, scratch, seq_axes, ring_mods)


def gather_prefix_blocks(pool: Any, scratch: Any, bt_row: jnp.ndarray,
                         n_tokens: jnp.ndarray, *, s_pad: int,
                         block_size: int, seq_axes: Any) -> Any:
    """Load a cached prefix into the prefill scratch: logical positions
    ``[0, n_tokens)`` of the chain behind ``bt_row`` are gathered from the
    paged pool into the scratch cache (positions past ``n_tokens`` keep
    their current scratch values).  The inverse of ``write_chunk_blocks``,
    used when prefix sharing lets prefill resume mid-prompt: the uncached
    tail's attention reads the shared prefix's K/V out of the scratch.

    ``n_tokens`` is a traced int32 scalar — one compilation serves every
    prefix length.  Table entries past the chain point at the null block;
    the ``log < n_tokens`` mask keeps that garbage out of the scratch.
    """
    log = jnp.arange(s_pad)
    phys = bt_row[log // block_size] * block_size + log % block_size
    keep = log < n_tokens

    def upd(sc, p, ax):
        pm = jnp.moveaxis(p, ax, 0)
        sm = jnp.moveaxis(sc, ax, 0)
        g = pm[phys].astype(sm.dtype)
        shape = (s_pad,) + (1,) * (sm.ndim - 1)
        sm = jnp.where(keep.reshape(shape), g, sm)
        return jnp.moveaxis(sm, 0, ax)

    return jax.tree.map(upd, scratch, pool, seq_axes)


def copy_block(pool: Any, src: jnp.ndarray, dst: jnp.ndarray, *,
               block_size: int, seq_axes: Any) -> Any:
    """Copy physical block ``src``'s KV positions onto block ``dst`` in
    every pool leaf — the device half of copy-on-write (the allocator's
    ``cow`` does the bookkeeping half).  ``src``/``dst`` are traced int32
    scalars, so one compilation serves every copy."""

    def upd(p, ax):
        pm = jnp.moveaxis(p, ax, 0)
        blk = jax.lax.dynamic_slice_in_dim(pm, src * block_size, block_size,
                                           axis=0)
        pm = jax.lax.dynamic_update_slice(
            pm, blk, (dst * block_size,) + (0,) * (pm.ndim - 1))
        return jnp.moveaxis(pm, 0, ax)

    return jax.tree.map(upd, pool, seq_axes)
