"""Paged KV pool: block allocator + block-table plumbing for the engine.

The slab pool (``slots.py``) reserves ``max_seq_len`` KV positions per slot
for the whole lifetime of a request, so short requests strand memory and
``max_slots`` is capped by the worst case.  The paged pool decouples the
two: physical KV memory is a pool of fixed-size blocks (``kv_block_size``
tokens each), and every request owns a *chain* of blocks that grows as its
sequence does.  A static-shape block table ``[max_slots,
max_blocks_per_slot]`` maps each slot's logical block index to a physical
block id; attention gathers K/V through it (see
``repro.models.attention.paged_decode_attention``).

Physical block 0 is the *null block*: unallocated table entries point at it,
so gathers/scatters through a partially-filled table stay in bounds —
reads from it are masked by the per-row ``cache_len`` validity mask, writes
to it land in garbage that nothing reads.

Layout discovery is shared with the slab pool: ``discover_seq_axes`` finds
every cache leaf's KV-length axis structurally, and the same axis indices
drive both the physical-pool construction and the chunk scatter here —
scan-stacked blocks and unscanned lead layers need no special cases.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


NULL_BLOCK = 0      # physical block id unallocated table entries point at


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV positions."""
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` physical KV blocks.

    Block ids are dense ints; id 0 is reserved as the null block and never
    handed out.  Each request (keyed by rid) owns an ordered chain of
    blocks — logical block ``j`` of the request lives in physical block
    ``chain[j]``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one usable block past the "
                             "reserved null block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, num_blocks))
        self._chains: Dict[int, List[int]] = {}

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.usable_blocks - len(self._free)

    def chain(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._chains.get(rid, ()))

    def alloc_chain(self, rid: int, n_blocks: int) -> Optional[List[int]]:
        """Allocate a fresh ``n_blocks``-long chain for ``rid``; None (and
        no allocation) if the free list cannot cover it."""
        if rid in self._chains:
            raise ValueError(f"rid {rid} already holds a chain")
        if n_blocks > len(self._free):
            return None
        chain = [self._free.popleft() for _ in range(n_blocks)]
        self._chains[rid] = chain
        return list(chain)

    def extend(self, rid: int) -> Optional[int]:
        """Append one block to ``rid``'s chain; None if the pool is dry."""
        if not self._free:
            return None
        blk = self._free.popleft()
        self._chains.setdefault(rid, []).append(blk)
        return blk

    def release(self, rid: int) -> int:
        """Return ``rid``'s chain to the free list; returns #blocks freed."""
        chain = self._chains.pop(rid, [])
        self._free.extend(chain)
        return len(chain)


# ----------------------------------------------------------------------
# Physical pool construction
# ----------------------------------------------------------------------
def assert_pageable(init_cache: Callable[[int, int], Any], s_ref: int,
                    seq_axes: Any) -> None:
    """Every cache leaf must expose a full-length KV axis at ``s_ref``.

    Leaves clamped below ``s_ref`` (sliding-window ring buffers) or with no
    KV axis at all (SSM state) evict/step in ways a block table cannot
    express yet — reject them up front with the offending shape.
    """
    shapes = jax.eval_shape(lambda: init_cache(1, s_ref))

    def check(leaf, ax):
        if ax < 0 or leaf.shape[ax] != s_ref:
            raise NotImplementedError(
                f"cache leaf {leaf.shape} is not pageable: its KV-length "
                f"axis is {'absent' if ax < 0 else 'clamped below'} "
                f"s_max={s_ref} (window-clamped ring buffers and SSM state "
                f"need a paged equivalent — ROADMAP follow-on)")
    jax.tree.map(check, shapes, seq_axes)


def make_paged_pool(init_cache: Callable[[int, int], Any], s_ref: int,
                    seq_axes: Any, num_blocks: int, block_size: int) -> Any:
    """Physical paged pool: each cache leaf of ``init_cache(1, s_ref)`` with
    its KV-length axis resized to ``num_blocks * block_size`` positions.

    Built structurally (not via ``init_cache(1, P)``) so window-clamping
    inside ``init_cache`` can never silently truncate the physical pool.
    """
    assert_pageable(init_cache, s_ref, seq_axes)
    shapes = jax.eval_shape(lambda: init_cache(1, s_ref))
    P = num_blocks * block_size

    def build(leaf, ax):
        shape = list(leaf.shape)
        shape[ax] = P
        return jnp.zeros(tuple(shape), leaf.dtype)
    return jax.tree.map(build, shapes, seq_axes)


# ----------------------------------------------------------------------
# Chunk scatter: scratch -> allocated blocks
# ----------------------------------------------------------------------
def write_chunk_blocks(pool: Any, scratch: Any, bt_row: jnp.ndarray,
                       start: jnp.ndarray, *, chunk: int, block_size: int,
                       seq_axes: Any) -> Any:
    """Scatter scratch positions ``[start, start + chunk)`` into the paged
    pool through one slot's block-table row.

    ``bt_row`` is the slot's ``[max_blocks_per_slot]`` int32 table row and
    ``start`` a traced int32 scalar (a chunk-aligned prefill offset), so one
    compilation serves every slot, chunk, and block assignment.  The chain
    behind ``bt_row`` must cover the whole chunk-rounded sequence (the
    engine allocates ``round_up(prefill_len, chunk)`` tokens of blocks at
    admission): pad positions past the prompt land in *real* allocated
    blocks, as garbage the validity mask keeps unread until decode
    overwrites it.  Only entries still parked on the null block (beyond the
    chain) write into discarded space.
    """
    log = start + jnp.arange(chunk)
    phys = bt_row[log // block_size] * block_size + log % block_size

    def upd(p, sc, ax):
        pm = jnp.moveaxis(p, ax, 0)
        sm = jnp.moveaxis(sc, ax, 0)
        ck = jax.lax.dynamic_slice_in_dim(sm, start, chunk, axis=0)
        pm = pm.at[phys].set(ck.astype(pm.dtype))
        return jnp.moveaxis(pm, 0, ax)

    return jax.tree.map(upd, pool, scratch, seq_axes)
