"""Step core of the serving engine: the jitted prefill/decode/verify
drivers and their key streams.

``StepCore`` owns everything that traces: the prefill-chunk entry, the
decode entry (which is the ``[B, k + 1]`` *verify* entry when speculative
decoding is on), and the deterministic PRNG streams that feed router skew
and sampling.  It holds no scheduling state — the engine passes in the
batch vectors (tokens, positions, active mask, block table) and replica /
residency tables each call, so one ``StepCore`` serves the ``unified``,
``prefill``-only, and ``decode``-only engine roles unchanged.

Every jitted signature is fixed at construction; ``jit_counts()`` exposes
the per-entry cache sizes that ``report()["jit_entries"]`` asserts stay
at one entry across admissions, recycling, growth, and role handoffs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.serve.sampling import sample_tokens


class StepCore:
    def __init__(self, model, ecfg, *, skew: bool,
                 moe_policy: Optional[str], layer_diags: bool):
        self.model = model
        self.ecfg = ecfg
        self.skew = skew
        self.sample = ecfg.temperature > 0
        self.spec = ecfg.speculative_k > 0
        self.moe_policy = moe_policy
        self.layer_diags = layer_diags

        self.base_key = jax.random.PRNGKey(ecfg.skew_seed)
        self.pf_key = jax.random.fold_in(self.base_key, 0)
        self.dec_key = jax.random.fold_in(self.base_key, 1)
        self.samp_rng = (np.random.default_rng(ecfg.skew_seed + 101)
                         if self.sample else None)

        if ecfg.paged:
            if self.spec:
                # speculative verify IS the decode step: one [B, k+1]
                # multi-token forward returning logits at every window
                # position; acceptance/sampling run host-side
                self.decode_fn = jax.jit(
                    lambda p, t, c, pos, bt, k, a, rep, res:
                        self._verify_core(p, t, c, pos, k, a, bt, rep, res))
            else:
                self.decode_fn = jax.jit(
                    lambda p, t, c, pos, bt, k, a, rep, res:
                        self._decode_core(p, t, c, pos, k, a, bt, rep, res))
        else:
            self.decode_fn = jax.jit(
                lambda p, t, c, pos, k, a, rep, res: self._decode_core(
                    p, t, c, pos, k, a, None, rep, res))
        # replica ids ride along as a trailing traced arg so between-window
        # weight swaps never re-trace (None = no replica slots: an empty
        # pytree, same trace either way).  With fused_paged_attention the
        # prefill chunk ALSO runs the q-tiled Pallas kernel: the slab
        # scratch is viewed as contiguous per-row blocks inside
        # attention_block's continue_prefill branch (strict — an
        # inapplicable fused path raises at warmup instead of silently
        # gathering); fused_moe_gmm routes the chunk's Bc * C expert
        # tokens through the grouped-GEMM kernel.
        pf_fused_attn = True if ecfg.fused_paged_attention else None
        pf_fused_moe = True if ecfg.fused_moe_gmm else None
        self.prefill_fn = jax.jit(
            lambda p, t, c, pos, last, key, rep: model.prefill_chunk(
                p, t, c, pos, last, key, moe_replica_ids=rep,
                fused_attention=pf_fused_attn, fused_moe=pf_fused_moe))

    # ------------------------------------------------------------------
    def next_key(self, stream_key, idx: int):
        if not (self.skew or self.sample):
            return None
        return jax.random.fold_in(stream_key, idx)

    def _decode_core(self, params, tok, pool, pos, key, active, bt, rep,
                     res=None):
        skew_key = samp_key = None
        if self.skew and self.sample:
            skew_key = jax.random.fold_in(key, 0)
            samp_key = jax.random.fold_in(key, 1)
        elif self.skew:
            skew_key = key
        elif self.sample:
            samp_key = key
        kw: Dict[str, Any] = {}
        if bt is not None:
            kw = dict(block_table=bt, block_size=self.ecfg.kv_block_size)
            if self.ecfg.fused_paged_attention:
                kw["fused_attention"] = True
        if self.ecfg.fused_moe_gmm:
            kw["fused_moe"] = True
        logits, pool, _, diags = self.model.decode_step(
            params, tok, pool, pos, skew_key=skew_key, active_mask=active,
            moe_policy=self.moe_policy, moe_replica_ids=rep,
            moe_residency_ids=res,
            moe_layer_diags=self.layer_diags, **kw)
        nxt = sample_tokens(logits, samp_key,
                            temperature=self.ecfg.temperature,
                            top_k=self.ecfg.top_k, top_p=self.ecfg.top_p)
        return nxt, pool, diags

    def _verify_core(self, params, toks, pool, pos, key, active, bt, rep,
                     res=None):
        """Speculative verify step: ``toks`` [B, k+1] (window position 0 =
        the committed last token, 1..k = drafts) -> logits [B, k+1, V] at
        every window position.  No in-jit sampling — greedy acceptance /
        rejection sampling run host-side on the returned logits (the key
        feeds router skew only, folded exactly like ``_decode_core``)."""
        skew_key = None
        if self.skew:
            skew_key = jax.random.fold_in(key, 0) if self.sample else key
        kw: Dict[str, Any] = dict(block_table=bt,
                                  block_size=self.ecfg.kv_block_size)
        if self.ecfg.fused_paged_attention:
            kw["fused_attention"] = True
        if self.ecfg.fused_moe_gmm:
            kw["fused_moe"] = True
        logits, pool, _, diags = self.model.decode_step(
            params, toks, pool, pos, skew_key=skew_key, active_mask=active,
            moe_policy=self.moe_policy, moe_replica_ids=rep,
            moe_residency_ids=res,
            moe_layer_diags=self.layer_diags, **kw)
        return logits, pool, diags

    # ------------------------------------------------------------------
    def jit_counts(self) -> Dict[str, int]:
        return {
            "prefill_chunk": self.prefill_fn._cache_size(),
            "decode": self.decode_fn._cache_size(),
        }
