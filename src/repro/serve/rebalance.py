"""Between-window hot-expert replication (paper §4.2, Fig. 7).

HarMoEny's scheduler (Alg. 2) rebalances *token units* every step, but a
single scorching expert still bottlenecks its host rank: units for one
expert cannot be split below the q-token granularity once every other rank
is saturated, and foreign-slot fetches pay the weight-transfer cost every
step. The paper's answer is to *replicate* the hottest experts' weights on
other ranks between serving windows, so the per-step scheduler can treat
them as zero-cost local destinations everywhere.

This module is the host-side policy half of that mechanism:

  * :class:`ExpertRebalancer` folds the per-step ``expert_load`` diagnostic
    (emitted by the MoE layer, [Ep] global token units per expert) into an
    EMA, and every ``rebalance_interval`` steps proposes a new replica-slot
    assignment: the top-R experts whose EMA load exceeds
    ``hot_threshold x mean`` get their weights copied into the R static
    replica slots of every *non-host* rank.

  * :class:`RebalanceDecision` carries the new ``replica_ids`` [G, R] array
    (fed to the jitted decode fn as a *traced* argument — swaps never
    recompile) plus ``weight_rows`` [G*R] — indices into the rank-major
    stacked expert-row axis that the engine's jitted swap fn gathers into
    the ``w_rep_*`` parameter leaves.

Shapes are static by construction: R slots exist from init (zero weights,
ids all -1), and a decision only changes array *values*. The engine keeps
exactly one jit cache entry across any number of swaps (asserted by
``report()["engine"]["recompiled_after_warmup"]``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.topology import EPTopology, local_slot_of


@dataclasses.dataclass(frozen=True)
class RebalanceDecision:
    """One proposed replica assignment (see module docstring)."""
    replica_ids: np.ndarray    # [G, R] int32, -1 = slot empty
    weight_rows: np.ndarray    # [G*R] int32 rows into the stacked expert axis
    hot_experts: List[int]     # replicated experts, hottest first
    changed: bool              # False => identical to the previous decision


class ExpertRebalancer:
    """EMA load tracker + greedy hot-expert replica placement.

    Parameters
    ----------
    topo:
        The serving model's expert-parallel topology (decode and prefill
        share it; replica ids are expressed in global expert ids).
    num_replica_slots:
        R, the static per-rank replica slot count (= MoEConfig value).
    ema_alpha:
        Weight of the newest step in the exponential moving average.
    hot_threshold:
        An expert is "hot" when ema[e] > hot_threshold * mean(ema). The
        paper uses mean-relative thresholds so uniform streams never
        trigger swaps regardless of absolute throughput.
    """

    def __init__(self, topo: EPTopology, num_replica_slots: int, *,
                 ema_alpha: float = 0.2, hot_threshold: float = 1.5):
        if num_replica_slots <= 0:
            raise ValueError("num_replica_slots must be > 0")
        if topo.hosts_per_expert != 1:
            raise ValueError(
                "hot-expert replication requires E >= num_ranks "
                "(each expert having a unique host)")
        self.topo = topo
        self.R = int(num_replica_slots)
        self.ema_alpha = float(ema_alpha)
        self.hot_threshold = float(hot_threshold)
        self.ema: Optional[np.ndarray] = None        # [Ep] float64
        # optional per-layer EMAs (observe(layer=...)): distinct MoE
        # layers can run disjoint hot sets, and a global EMA blurs them —
        # the residency manager's prefetch predictor reads these, while
        # hot()/propose() keep reading the global EMA (replica slots are
        # shared across layers, so placement stays layer-agnostic)
        self.layer_ema: Dict[int, np.ndarray] = {}
        self.steps_observed = 0
        self._lsl = local_slot_of(topo)              # [G, Ep]
        self._last_ids = np.full(
            (topo.num_ranks, self.R), -1, np.int32)  # init state: all empty

    # ---------------------------------------------------------------- observe
    def observe(self, expert_load: np.ndarray,
                layer: Optional[int] = None) -> None:
        """Fold one step's [Ep] global expert-load vector into the EMA.

        With ``layer`` the load is *additionally* folded into that
        layer's own EMA (``layer_ema[layer]``, created on first use) —
        the global EMA updates identically either way, so callers that
        never pass ``layer`` see exactly the historical behavior."""
        v = np.asarray(expert_load, np.float64).reshape(-1)
        if v.shape[0] != self.topo.padded_experts:
            raise ValueError(
                f"expert_load has {v.shape[0]} entries, topology expects "
                f"{self.topo.padded_experts}")
        if self.ema is None:
            self.ema = v.copy()
        else:
            self.ema = (1.0 - self.ema_alpha) * self.ema + self.ema_alpha * v
        if layer is not None:
            prev = self.layer_ema.get(int(layer))
            self.layer_ema[int(layer)] = v.copy() if prev is None \
                else (1.0 - self.ema_alpha) * prev + self.ema_alpha * v
        self.steps_observed += 1

    # ---------------------------------------------------------------- propose
    def hot(self) -> List[int]:
        """Top-R hot experts by EMA (hottest first); [] before any observe.

        Padding experts (E <= e < Ep) are routed no tokens and therefore
        can never exceed the mean-relative threshold.
        """
        if self.ema is None:
            return []
        mean = float(self.ema.mean())
        if mean <= 0.0:
            return []
        order = np.argsort(-self.ema, kind="stable")
        out: List[int] = []
        for e in order[: self.R]:
            if self.ema[e] > self.hot_threshold * mean:
                out.append(int(e))
        return out

    def propose(self) -> RebalanceDecision:
        """Greedy placement: hot expert r -> replica slot r of every rank
        except its host (the host already serves it from a local slot).

        Empty slots keep id -1 and point their weight row at row 0 — the
        gathered weights are dead (never scheduled to) but the gather must
        stay in-bounds with static shapes.
        """
        topo = self.topo
        G, epr = topo.num_ranks, topo.experts_per_rank
        hot = self.hot()
        ids = np.full((G, self.R), -1, np.int32)
        rows = np.zeros((G * self.R,), np.int32)
        for r, e in enumerate(hot):
            host = int(topo.host_of[e, 0])
            src_row = host * epr + int(self._lsl[host, e])
            for g in range(G):
                if g == host:
                    continue                      # local slot already serves e
                ids[g, r] = e
                rows[g * self.R + r] = src_row
        changed = not np.array_equal(ids, self._last_ids)
        if changed:
            self._last_ids = ids.copy()
        return RebalanceDecision(replica_ids=ids, weight_rows=rows,
                                 hot_experts=hot, changed=changed)
