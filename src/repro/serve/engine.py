"""Continuous-batching serving engine.

The engine is composed from three components (one file each):

* ``serve/frontend.py`` — ``AdmissionFront``: the arrival queue, free-slot
  pool, prefill pipeline, and preempted-recompute queue, plus the
  admission loop;
* ``serve/stepcore.py`` — ``StepCore``: the jitted prefill/decode/verify
  drivers and their deterministic key streams;
* ``serve/kvstore.py`` — ``KVOwner``: the physical KV pool (slab or
  paged), block allocator + prefix index, prefill scratch, and the jitted
  KV-movement primitives — including the prefill→decode *handoff*
  (``HandoffRecord``) that serializes a finished prefill's block-chain
  contents for a decode-role engine to import token-exactly.

``ServeEngine`` keeps the scheduling state that ties them together (slot
vectors, the decode batch, preemption, metrics) and drives jitted
functions with fixed signatures:

* ``model.prefill_chunk`` on a ``[1, prefill_chunk]`` scratch cache —
  newcomers' prompts are consumed chunk-by-chunk, interleaved with decode
  steps;
* ``model.decode_step`` on the full pool with a per-slot position vector —
  every occupied slot advances one token per step regardless of how long
  each sequence already is.  With ``EngineConfig.speculative_k`` the
  decode entry becomes a ``[B, k + 1]`` *verify* step instead: up to k
  self-drafted tokens per slot are scored in one forward and the accepted
  prefix (plus one token from the verify logits) is committed — 1 to
  k + 1 tokens per step, token-exact for greedy streams
  (``serve/speculative.py``; paged only).

Two pool layouts:

* **slab** (default): ``model.init_cache(max_slots, max_seq_len)`` — each
  slot owns a worst-case-length row; a finished prefill is scattered into
  its slot with ``write_slot`` (traced slot index).
* **paged** (``EngineConfig.paged``): a physical pool of ``num_kv_blocks``
  fixed-size blocks plus a ``[max_slots, max_blocks_per_slot]`` block table
  (see ``paging.py``).  Admission is gated on *free blocks* rather than
  free slots alone, block chains grow incrementally as decode advances,
  blocks are reclaimed the moment a request finishes, and when the
  allocator runs dry the youngest block-holding request is preempted and
  later *recomputed* (its prompt plus committed tokens re-prefilled).
  Finished prefill chunks are scattered straight into allocated blocks
  (``write_chunk_blocks``), and decode gathers K/V through the table.
  With ``EngineConfig.prefix_sharing`` the pool becomes a prefix-sharing
  cache: admission maps each request's longest radix-indexed token prefix
  into its chain with refcount bumps and prefills only the uncached tail
  (the cached prefix is gathered into the prefill scratch), shared blocks
  are copy-on-write, and dead indexed blocks are retained on an LRU
  cached-free list until allocation pressure evicts them (see
  ``paging.py`` and README "Prefix caching").

Engine **roles** (``EngineConfig.role``; paged only for the split roles):

* ``unified`` (default) — prefill and decode on one engine, as above.
* ``prefill`` — runs admission + chunked prefill only.  When a request's
  prefill finishes (first token sampled), instead of joining the decode
  batch it is exported as a ``HandoffRecord`` (block-chain KV + committed
  tokens + timestamps), its blocks are released (indexed prefixes stay
  cached), and the record is queued for ``pop_handoffs()``.
* ``decode`` — admits work only via ``import_handoff(record)``: the KV is
  scattered into its own pool through the same jitted
  ``write_chunk_blocks`` entry ordinary prefill uses, and the request
  joins the decode batch exactly where the exporter left it.  Greedy
  streams are token-identical to a unified engine serving the same
  requests.  (A decode-role engine still prefills when it must: a
  preempted request's recompute runs on the importing engine.)

Because every array shape — including the block table — is fixed at engine
construction, the jit caches hold exactly one entry each across admissions,
slot recycling, block growth, preemption, EOS, and role handoffs —
``report()["jit_entries"]`` asserts this is so.

Requests enter through an ``AdmissionQueue`` (Poisson or trace-driven
arrivals); freed slots are immediately re-admitted from the queue
(preempted requests first).  Per-step MoE schedule diagnostics
(moved_units, drops, max_load), KV-block occupancy, and per-request
TTFT/TPOT/e2e flow into ``ServeMetrics``.

Per-sequence state is owned by a ``serve/statestore.py``
``SequenceStateStore``: ``KVOwner`` (token-indexed K/V, slab or paged —
sliding-window layers are served paged as ring buffers, see kvstore.py)
for transformer families, and the slotted ``SlotStateStore`` for SSM and
hybrid families, whose recurrent state is fixed-size per slot.  The
engine addresses state only through the protocol (admission planning,
begin-prefill scratch reset, the write/gather/release primitives), so
scheduling — continuous batching, chunked prefill, preemption-by-
recompute — is identical across state kinds.

Scope: decoder-only transformer (dense and MoE), SSM, and hybrid
families; the mesh may shard the model/expert axis but not the batch
axis.  Encoder-decoder and prefix-embedding models are follow-ons; split
roles, prefix sharing, and speculative decoding remain paged-transformer
features (EngineConfig.validate + the ring/SSM checks here spell out
each combination's status).
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import round_up
from repro.core.prefetch import stage_expert_rows
from repro.kernels.paged_attention.ops import largest_block_divisor
from repro.models import attention as attention_dispatch
from repro.serve.arrivals import WallClock
from repro.serve.frontend import AdmissionFront
from repro.serve.kvstore import HandoffRecord
from repro.serve.metrics import ServeMetrics
from repro.serve.statestore import make_state_store
from repro.serve.paging import NULL_BLOCK, blocks_for_tokens
from repro.serve.rebalance import ExpertRebalancer
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.residency import (PREFETCH_POLICIES, ExpertResidencyManager,
                                   TierCostModel)
from repro.serve.sampling import sample_np
from repro.serve.speculative import (greedy_verify, make_proposer,
                                     rejection_verify)
from repro.serve.stepcore import StepCore

ENGINE_ROLES = ("unified", "prefill", "decode")


@dataclass(frozen=True)
class EngineConfig:
    """Static serving shapes — these fix every jitted signature."""
    max_slots: int = 4          # decode batch width (concurrent requests)
    max_seq_len: int = 128      # logical KV length (prompt + generation)
    prefill_chunk: int = 32     # prompt tokens consumed per prefill call
    chunks_per_step: int = 1    # prefill chunks interleaved per engine step
    eos_id: Optional[int] = None
    skew_seed: int = 0          # synthetic router-skew + sampling key stream
    # --- engine role (fleet disaggregation; see module docstring) ---
    # "unified" serves prefill + decode; "prefill" exports finished
    # prefills as HandoffRecords; "decode" imports them.  The split roles
    # hand KV off through the block machinery, so they require paged.
    role: str = "unified"
    # --- paged KV pool ---
    paged: bool = False
    kv_block_size: int = 16     # tokens per physical KV block
    num_kv_blocks: int = 0      # usable blocks (0 = worst case: slab parity)
    # fused Pallas paged-attention decode kernel (kernels/paged_attention):
    # reads K/V block-wise through the block table inside the kernel
    # instead of gathering each row's [L_max] logical view (paged only;
    # interpret mode off-TPU)
    fused_paged_attention: bool = False
    # fused grouped-GEMM Pallas expert FFN (kernels/moe_gmm) for the
    # decode/verify/prefill expert path (MoE models only; interpret mode
    # off-TPU): the scheduled expert batches run one tiled kernel instead
    # of per-expert dense matmuls
    fused_moe_gmm: bool = False
    # --- prefix sharing (paged only) ---
    prefix_sharing: bool = False
    # --- speculative decoding (paged only) ---
    # k > 0: each decode step verifies up to k self-drafted tokens in one
    # static-shape [B, k + 1] forward (serve/speculative.py); greedy
    # streams stay token-identical, sampled streams distribution-identical
    speculative_k: int = 0
    speculative_policy: str = "ngram"   # draft proposer (make_proposer)
    # --- sampling (0 temperature = greedy) ---
    temperature: float = 0.0
    top_k: int = 0              # 0 = full vocab when temperature > 0
    top_p: float = 1.0          # nucleus truncation (1.0 = disabled)
    # --- MoE load balancing (MoE models only) ---
    # decode scheduling policy override (None = the model config's policy):
    # harmoeny / round_robin / even_split / static_opt (core/scheduler.py)
    moe_policy: Optional[str] = None
    # between-window hot-expert replication (serve/rebalance.py): every
    # `rebalance_interval` engine steps the EMA-hottest experts' weights are
    # swapped into the model's static replica slots.  Requires the model to
    # be built with MoEConfig.num_replica_slots == replica_slots (the slots
    # exist from init, so swaps never change shapes or recompile).
    rebalance_interval: int = 0
    replica_slots: int = 0
    # tiered expert residency (serve/residency.py): keep only
    # `resident_experts` expert working-set rows (pod total, split evenly
    # across EP ranks) "HBM-resident"; the rest live in the emulated host
    # tier and are staged in per `prefetch_policy` — `predictive`
    # (EMA-predicted next-layer prefetch, stalls hidden), `on_demand`
    # (stage on first touch, stall every miss), or `none` (frozen initial
    # working set).  0 = residency off (everything device-resident).
    resident_experts: int = 0
    prefetch_policy: str = "predictive"

    def __post_init__(self):
        self.validate()

    def validate(self) -> "EngineConfig":
        """Every model-independent legality check, in one place.

        ``__post_init__`` runs this on construction, so an ``EngineConfig``
        that exists is valid; call sites that build configs field-by-field
        (flag plumbing, tests) can also invoke it directly.  Model-
        *dependent* checks (MoE-only knobs against non-MoE models, replica
        slot counts, sliding-window ring restrictions, family/mesh
        support) live in ``ServeEngine.__init__``/``make_state_store``,
        where the model is in hand.  Returns ``self`` for chaining."""
        # --- shapes ---
        if self.max_slots < 1 or self.max_seq_len < 1:
            raise ValueError("max_slots and max_seq_len must be >= 1")
        if self.prefill_chunk < 1 or self.chunks_per_step < 1:
            raise ValueError("prefill_chunk and chunks_per_step must be "
                             ">= 1")
        # --- role ---
        if self.role not in ENGINE_ROLES:
            raise ValueError(f"unknown engine role {self.role!r}; choose "
                             f"one of {ENGINE_ROLES}")
        if self.role != "unified" and not self.paged:
            raise ValueError(
                "prefill/decode engine roles hand KV off through the paged "
                "block machinery; they require EngineConfig.paged=True")
        # --- paged pool ---
        if self.paged and self.kv_block_size < 1:
            raise ValueError("kv_block_size must be >= 1")
        if self.num_kv_blocks < 0:
            raise ValueError("num_kv_blocks must be >= 0 (0 = slab-parity "
                             "worst case)")
        if self.prefix_sharing and not self.paged:
            raise ValueError("prefix_sharing requires the paged KV pool "
                             "(EngineConfig.paged=True)")
        if self.fused_paged_attention and not self.paged:
            raise ValueError("fused_paged_attention is the paged decode "
                             "kernel; it requires EngineConfig.paged=True")
        # --- speculative decoding ---
        if self.speculative_k < 0:
            raise ValueError("speculative_k must be >= 0")
        if self.speculative_k > 0 and not self.paged:
            raise ValueError("speculative decoding verifies through the "
                             "paged KV pool (rollback rides the block "
                             "machinery); it requires EngineConfig."
                             "paged=True")
        # --- sampling ---
        if self.temperature < 0 or self.top_k < 0:
            raise ValueError("temperature and top_k must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        # --- MoE serving knobs ---
        known = ("harmoeny", "round_robin", "even_split", "static_opt")
        if self.moe_policy is not None and self.moe_policy not in known:
            raise ValueError(f"unknown moe_policy {self.moe_policy!r}; "
                             f"choose one of {known}")
        if self.replica_slots < 0 or self.rebalance_interval < 0:
            raise ValueError("replica_slots and rebalance_interval must "
                             "be >= 0")
        if self.rebalance_interval > 0 and self.replica_slots == 0:
            raise ValueError("rebalance_interval > 0 needs replica_slots "
                             "> 0 (there is nowhere to place hot experts)")
        if self.resident_experts < 0:
            raise ValueError("resident_experts must be >= 0")
        if self.prefetch_policy not in PREFETCH_POLICIES:
            raise ValueError(
                f"unknown prefetch_policy {self.prefetch_policy!r}; choose "
                f"one of {PREFETCH_POLICIES}")
        return self


def paged_pool_len(max_seq_len: int, prefill_chunk: int,
                   prefix_sharing: bool, speculative_k: int = 0) -> int:
    """Chunk-padded logical pool length of the paged engine.  Prefix
    sharing pads one extra chunk: its prefill restarts (a block boundary,
    or ``prompt_len - 1`` on a full hit) are not chunk-aligned, so the
    final padded chunk can spill one chunk past the plain bound.
    Speculative decoding pads ``speculative_k`` extra tokens: a verify
    step writes all k + 1 window positions unconditionally (static
    shape), so a slot one token short of ``max_seq_len`` still scatters
    k positions past it — those writes must land inside the slot's own
    chain, never clamp into a neighbouring block.  Shared between the
    engine's ``_s_pad`` and ``engine_config_for``'s sliding-window
    validation so the two can never drift."""
    return round_up(max_seq_len, prefill_chunk) \
        + (prefill_chunk if prefix_sharing else 0) + speculative_k


class ServeEngine:
    def __init__(self, model, params, ecfg: EngineConfig, *, mesh=None,
                 clock=None):
        cfg = model.cfg
        if cfg.is_encoder_decoder or cfg.num_prefix_embeddings:
            raise NotImplementedError(
                f"serve engine supports decoder-only transformer, SSM, "
                f"and hybrid families; got {cfg.name} ({cfg.family})")
        extra = 1
        for ax, n in model.mesh_shape.sizes.items():
            if ax != "model":
                extra *= n
        if extra > 1:
            raise NotImplementedError(
                "serve engine shards the model/expert axis only; run "
                "with data=1 (data-parallel serving is an open item)")
        ecfg.validate()        # field-by-field call sites bypass init

        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.cfg = cfg
        self.mesh = mesh
        self.clock = clock or WallClock()
        self.metrics = ServeMetrics()
        self.role = ecfg.role

        self._skew = bool(cfg.is_moe and cfg.moe.router_skew > 0)
        self._sample = ecfg.temperature > 0
        self._spec = ecfg.speculative_k > 0
        # --- MoE load balancing / hot-expert replication ---
        if (ecfg.moe_policy is not None or ecfg.replica_slots > 0) \
                and not cfg.is_moe:
            raise ValueError("moe_policy / replica_slots need an MoE model")
        if ecfg.fused_moe_gmm and not cfg.is_moe:
            raise ValueError("fused_moe_gmm is the grouped-GEMM expert "
                             "FFN kernel; it needs an MoE model")
        self._moe_policy = ecfg.moe_policy
        self._rebalancer: Optional[ExpertRebalancer] = None
        self._replica_ids: Optional[np.ndarray] = None
        self._rebalances = 0
        self._replica_swaps = 0
        if ecfg.replica_slots > 0:
            spec = model.moe_spec
            if spec is None or spec.tp_mode:
                raise ValueError(
                    "hot-expert replication needs expert-parallel MoE "
                    "(num_experts >= the mesh model degree)")
            if cfg.moe.num_replica_slots != ecfg.replica_slots:
                raise ValueError(
                    f"EngineConfig.replica_slots={ecfg.replica_slots} but "
                    f"the model was built with MoEConfig.num_replica_slots="
                    f"{cfg.moe.num_replica_slots}; the slots must exist "
                    f"from init so swaps never change parameter shapes")
            topo = spec.topo
            self._rebalancer = ExpertRebalancer(topo, ecfg.replica_slots)
            self._replica_ids = np.full(
                (topo.num_ranks, ecfg.replica_slots), -1, np.int32)
            self._swap_fn = jax.jit(_swap_replica_weights)
        # --- tiered expert residency (serve/residency.py) ---
        self._residency: Optional[ExpertResidencyManager] = None
        self._residency_ids: Optional[np.ndarray] = None
        self._pending_stage = None        # decision applied next step start
        self._residency_stages = 0        # staging scatters dispatched
        self._res_base: Optional[Dict[str, float]] = None
        if ecfg.resident_experts > 0:
            spec = model.moe_spec
            if not cfg.is_moe or spec is None or spec.tp_mode:
                raise ValueError(
                    "tiered expert residency needs expert-parallel MoE "
                    "(num_experts >= the mesh model degree)")
            topo = spec.topo
            # the emulated host tier: one host-side copy of every expert
            # weight leaf, in the deterministic order the staging walk
            # visits them.  Device params stay authoritative (compute is
            # bit-exact at any budget); the staged writes copy identical
            # values, emulating the PCIe traffic the cost model prices.
            self._host_tier = [np.asarray(w)
                               for w in _collect_expert_leaves(params)]
            if not self._host_tier:
                raise ValueError("tiered expert residency found no expert "
                                 "weight leaves in the parameter tree")
            rows_axis = self._host_tier[0].ndim - 3
            n_rows = self._host_tier[0].shape[rows_axis]
            expert_bytes = float(sum(h.nbytes // h.shape[h.ndim - 3]
                                     for h in self._host_tier))
            assert n_rows == topo.num_ranks * topo.experts_per_rank
            self._residency = ExpertResidencyManager(
                topo, ecfg.resident_experts, policy=ecfg.prefetch_policy,
                cost=TierCostModel(expert_bytes=expert_bytes))
            self._residency_ids = self._residency._last_ids.copy()
            # one padded stage width => one jit entry across all swaps
            self._stage_width = max(
                topo.num_ranks * self._residency.W, 1)
            self._stage_fn = jax.jit(_stage_resident_weights)
        self._proposer = (make_proposer(ecfg.speculative_policy)
                          if self._spec else None)

        # --- jitted step drivers + key streams (serve/stepcore.py) ---
        self.core = StepCore(model, ecfg, skew=self._skew,
                             moe_policy=self._moe_policy,
                             layer_diags=self._residency is not None)

        self._paged = ecfg.paged
        self._sharing = ecfg.prefix_sharing
        B, C = ecfg.max_slots, ecfg.prefill_chunk
        if self._paged:
            bs = ecfg.kv_block_size
            # prefill writes whole padded chunks, so a slot's chain must
            # cover the chunk-rounded logical length (one extra chunk with
            # prefix sharing — see paged_pool_len)
            s_pad = paged_pool_len(ecfg.max_seq_len, C, self._sharing,
                                   ecfg.speculative_k)
            bps = blocks_for_tokens(s_pad, bs)
            w = cfg.sliding_window or 0
            if 0 < w <= bps * bs:
                # window-clamped layers are served as ring buffers
                # (kvstore ring_mods + paged_ring_decode_attention):
                # logical positions wrap modulo M = round_up(window, bs).
                # Ring contents depend on a sequence's absolute length,
                # and the ring gather is single-query — so the features
                # that re-read or hand off block contents are out.
                M = round_up(w, bs)
                blockers = []
                if ecfg.prefill_chunk > M:
                    blockers.append(
                        f"prefill_chunk {ecfg.prefill_chunk} > ring "
                        f"{M} tokens (a chunk must never self-overlap "
                        f"a ring slot; shrink prefill_chunk)")
                if ecfg.speculative_k > 0:
                    blockers.append("speculative verify is multi-query; "
                                    "the ring gather is single-query")
                if self._sharing:
                    blockers.append("prefix sharing keys blocks by "
                                    "content, but a ring slot's content "
                                    "depends on absolute sequence length")
                if ecfg.fused_paged_attention:
                    blockers.append("the fused paged kernel has no ring "
                                    "arithmetic")
                if ecfg.role != "unified":
                    blockers.append("KV handoff replays absolute-"
                                    "position scatters, not ring writes")
                if blockers:
                    raise ValueError(
                        f"{cfg.name} (sliding_window={w}) serves paged "
                        f"through the window ring buffer, which rejects: "
                        + "; ".join(blockers))
        else:
            s_pad = ecfg.max_seq_len
        # --- sequence-state store (serve/statestore.py): KVOwner for
        # transformer K/V, SlotStateStore for SSM/hybrid recurrent state —
        # the engine talks only to the SequenceStateStore protocol ---
        self.kv = make_state_store(model, ecfg, s_pad=s_pad, ctx=self._ctx)
        # --- admission/scheduling front (serve/frontend.py) ---
        self.front = AdmissionFront(B)
        self._register_sections()

        self.pos = np.zeros((B,), np.int32)      # per-slot sequence length
        self.tok = np.zeros((B,), np.int32)      # per-slot last token
        self.active = np.zeros((B,), bool)       # slot in the decode batch
        self._step_idx = 0
        self._chunk_idx = 0
        # allocator lifetime counters at window start (report() deltas)
        self._evict0 = 0
        self._cow0 = 0
        self._warm_counts: Optional[Dict[str, int]] = None
        # --- prefill→decode handoff state (split roles) ---
        self._handoffs_out: deque = deque()      # exported, awaiting pickup
        self.handoffs_exported = 0
        self.handoffs_imported = 0
        self.handoff_bytes_out = 0
        self.handoff_bytes_in = 0
        # --- per-phase attention byte model (metrics.record_phase) ---
        # bytes one KV token costs to read across the stack (K + V, every
        # layer), and the slab block size the fused prefill path derives —
        # must mirror attention_block's largest_block_divisor choice so the
        # analytic bytes match what the kernel's causal pruning touches
        kvb = {"float32": 4, "bfloat16": 2}.get(cfg.dtype, 4)
        self._kv_token_bytes = (2 * cfg.num_layers
                                * (cfg.num_kv_heads or cfg.num_heads)
                                * cfg.resolved_head_dim * kvb)
        self._scratch_len = self.kv.s_pad
        self._slab_bs = largest_block_divisor(self._scratch_len)
        # attention dispatch-log snapshot taken right after warmup's traces;
        # when warmup() is skipped (tests drive run() directly) report()
        # falls back to the live log, which this reset scopes to the
        # engine built last
        self._attn_dispatch: Optional[List[Dict[str, Any]]] = None
        attention_dispatch.reset_dispatch_log()

    # ------------------------------------------------------------------
    # component delegation — the pre-refactor attribute surface.  Tests,
    # benchmarks, and the fleet router address engine state through these
    # names; they forward to the owning component.
    # ------------------------------------------------------------------
    @property
    def _alloc(self):
        return self.kv.alloc

    @property
    def pool(self):
        return self.kv.pool

    @pool.setter
    def pool(self, v):
        self.kv.pool = v

    @property
    def _scratch(self):
        return self.kv.scratch

    @_scratch.setter
    def _scratch(self, v):
        self.kv.scratch = v

    @property
    def block_table(self):
        return self.kv.block_table

    @property
    def blocks_per_slot(self):
        return self.kv.blocks_per_slot

    @property
    def kv_capacity(self):
        return self.kv.kv_capacity

    @property
    def _s_pad(self):
        return self.kv.s_pad

    @property
    def _seq_axes(self):
        return self.kv.seq_axes

    @property
    def _write_fn(self):
        return self.kv.write_fn

    @property
    def _gather_fn(self):
        return self.kv.gather_fn

    @property
    def _copy_fn(self):
        return self.kv.copy_fn

    @property
    def _prefill_fn(self):
        return self.core.prefill_fn

    @property
    def _decode_fn(self):
        return self.core.decode_fn

    @property
    def _base_key(self):
        return self.core.base_key

    @property
    def _pf_key(self):
        return self.core.pf_key

    @property
    def _dec_key(self):
        return self.core.dec_key

    @property
    def _samp_rng(self):
        return self.core.samp_rng

    @property
    def queue(self):
        return self.front.queue

    @property
    def free_slots(self):
        return self.front.free_slots

    @property
    def state_by_slot(self):
        return self.front.state_by_slot

    @property
    def slot_history(self):
        return self.front.slot_history

    @property
    def _pf(self):
        return self.front.pf

    @_pf.setter
    def _pf(self, v):
        self.front.pf = v

    @property
    def _pf_queue(self):
        return self.front.pf_queue

    @property
    def _resume(self):
        return self.front.resume

    @property
    def _admit_seq(self):
        return self.front.admit_seq

    @_admit_seq.setter
    def _admit_seq(self, v):
        self.front.admit_seq = v

    # ------------------------------------------------------------------
    def _ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _eos_id(self, req: Request) -> Optional[int]:
        """Per-request EOS override, falling back to the engine default."""
        return req.eos_id if req.eos_id is not None else self.ecfg.eos_id

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if self.role == "decode":
            raise ValueError(
                "decode-role engine takes work via import_handoff(), not "
                "submit(); route arrivals to a prefill or unified engine")
        L, C = req.prompt_len, self.ecfg.prefill_chunk
        if round_up(L, C) > self.kv_capacity:
            raise ValueError(
                f"request {req.rid}: prompt of {L} (padded to "
                f"{round_up(L, C)}) exceeds the per-layer KV capacity "
                f"{self.kv_capacity}")
        if L + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {L} + max_new "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.ecfg.max_seq_len}")
        self.queue.push(req)

    def has_work(self) -> bool:
        return bool(len(self.queue) or self._in_flight())

    def _in_flight(self) -> bool:
        """Admitted work whose timestamps already live on the current clock
        (queued-but-unadmitted requests carry none — their arrival_time is
        relative to the measurement window, not the clock origin).
        Preempted requests and exported-but-unclaimed handoffs hold
        timestamps too."""
        return self.front.in_flight(bool(self.active.any())) \
            or bool(self._handoffs_out)

    # ------------------------------------------------------------------
    # admission (block-aware in paged mode; preempted requests first)
    # ------------------------------------------------------------------
    def _share_plan(self, tokens, resumed: bool) -> Tuple[int, List[int],
                                                          int, bool]:
        return self.kv.share_plan(tokens, resumed)

    def _can_admit(self, plan) -> bool:
        return self.kv.can_admit(plan)

    def _place(self, st: RequestState, now: float, plan=None) -> None:
        slot = self.free_slots.popleft()
        st.slot = slot
        st.status = RequestStatus.PREFILL
        st.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.state_by_slot[slot] = st
        self.slot_history.append((st.req.rid, slot))
        if self._paged:
            start, shared, n_fresh, cow_last = plan
            chain = self._alloc.alloc_chain(st.req.rid, n_fresh,
                                            shared=shared)
            assert chain is not None      # gated by the caller
            if cow_last:
                # full-prompt hit: the last-position recompute writes into
                # the final shared block — give this chain a private copy
                ok = self._cow_block(st, len(shared) - 1)
                assert ok                 # the CoW block was gated too
            st.prefill_pos = start
            # nothing to gather when no cached prefix was mapped: prefill
            # starts at 0 and builds the scratch itself
            st.prefix_loaded = start == 0
            if st.n_preempted == 0:
                st.cached_prefix_tokens = start
            elif self._sharing:
                self.metrics.resume_cached_tokens += start
            if st.resumed and start >= st.prefill_len:
                # full-sequence hit on recompute: every committed position's
                # K/V is already cached — no prefill at all, the pending
                # last token decodes next step
                self._activate(st, st.prefill_len, st.output[-1])
                return
        # self.block_table[slot] stays all-null until the slot joins
        # the decode batch: decode steps write every row's (garbage,
        # for inactive rows) K/V through the table, and a real entry
        # here would let that garbage clobber the mid-prefill blocks.
        # Prefill writes go through _bt_row(st) instead.
        self._pf_queue.append(st)

    def _bt_row(self, st: RequestState) -> np.ndarray:
        """This request's block-table row, built from its live chain (the
        engine-visible table row may still be parked on the null block)."""
        return self.kv.bt_row(st.req.rid)

    def _activate(self, st: RequestState, pos: int, tok: int) -> None:
        """Move a finished prefill into the decode batch."""
        s = st.slot
        st.status = RequestStatus.DECODE
        self.pos[s] = pos
        self.tok[s] = tok
        self.active[s] = True
        if self._paged:
            self.block_table[s] = self._bt_row(st)

    def _admit(self, now: float) -> None:
        self.front.admit(now, paged=self._paged, plan_fn=self._share_plan,
                         can_admit_fn=self._can_admit, place_fn=self._place)

    # ------------------------------------------------------------------
    # preemption: drop a request's state, recompute on re-admission.
    # Allocator pressure triggers it in paged mode (reclaim the youngest
    # holder's blocks); any store supports it — a slot store's state is
    # rebuilt token-exactly by re-prefilling prompt + committed output.
    # ------------------------------------------------------------------
    def _youngest_holder(self) -> Optional[RequestState]:
        cands = [st for st in self.state_by_slot if st is not None]
        return max(cands, key=lambda st: st.admit_seq) if cands else None

    def _preempt(self, st: RequestState) -> None:
        s = st.slot
        self.kv.release(st.req.rid, s)
        self.active[s] = False
        self.pos[s] = 0
        self.tok[s] = 0
        self.state_by_slot[s] = None
        self.free_slots.append(s)
        if self._pf is st:
            self._pf = None
        elif st in self._pf_queue:
            self._pf_queue.remove(st)
        st.slot = -1
        st.status = RequestStatus.QUEUED
        st.prefill_pos = 0
        st.prefix_loaded = False
        st.n_preempted += 1
        self._resume.append(st)
        self.metrics.preemptions += 1

    def _reclaim_until(self, st: RequestState, op):
        """Run allocator ``op`` (returns None while the pool is dry),
        preempting the youngest block holder between attempts.  Returns
        the op's result, or None if ``st`` itself was preempted to make
        room."""
        while True:
            res = op()
            if res is not None:
                return res
            victim = self._youngest_holder()
            if victim is None:
                raise RuntimeError("KV allocator dry with no block holders")
            self._preempt(victim)
            if victim is st:
                return None

    def _cow_block(self, st: RequestState, j: int) -> bool:
        """Give ``st`` a private copy of logical block ``j`` before a write
        would mutate it, preempting younger holders while the pool is dry.
        Returns False if ``st`` itself was preempted to make room."""
        res = self._reclaim_until(st, lambda: self._alloc.cow(st.req.rid, j))
        if res is None:
            return False
        old, new = res
        with self._ctx():
            self.pool = self._copy_fn(self.pool, np.int32(old),
                                      np.int32(new))
        if st.slot >= 0 and self.active[st.slot]:
            self.block_table[st.slot, j] = new
        return True

    def _grow_chain(self, st: RequestState) -> bool:
        """Extend ``st``'s block chain by one, preempting younger holders
        while the allocator is dry.  Returns False if ``st`` itself was the
        youngest and got preempted to make room."""
        blk = self._reclaim_until(st,
                                  lambda: self._alloc.extend(st.req.rid))
        if blk is None:
            return False
        n = len(self._alloc.chain(st.req.rid))
        self.block_table[st.slot, n - 1] = blk
        return True

    def _ensure_decode_blocks(self) -> None:
        """Before a decode step, every active slot needs its chain to cover
        the write range ``[pos[s], pos[s] + speculative_k]`` (a verify step
        writes all k + 1 window positions unconditionally; plain decode is
        the k = 0 case) — grow incrementally, oldest requests first so
        scarce blocks go to the work closest to finishing."""
        if self.kv.ring_full_chain:
            # every KV leaf wraps the fixed ring: chains were allocated
            # whole at admission and never grow
            return
        bs = self.ecfg.kv_block_size
        span = self.ecfg.speculative_k
        order = sorted(np.nonzero(self.active)[0],
                       key=lambda s: self.state_by_slot[s].admit_seq)
        for s in order:
            if not self.active[s]:        # preempted earlier in this pass
                continue
            st = self.state_by_slot[s]
            last = self.pos[s] + span     # deepest position written
            if self._sharing:
                # copy-on-write guard: every block this step writes into
                # must be private to this chain (a shared block is
                # immutable — and rejected-draft positions write garbage,
                # which must never land in another chain's prefix)
                preempted = False
                for j in range(self.pos[s] // bs, last // bs + 1):
                    chain = self._alloc.chain(st.req.rid)
                    if j < len(chain) \
                            and self._alloc.refcount(chain[j]) > 1:
                        if not self._cow_block(st, j):
                            preempted = True  # st itself evicted for room
                            break
                if preempted:
                    continue
            while len(self._alloc.chain(st.req.rid)) * bs <= last:
                if not self._grow_chain(st):
                    break

    # ------------------------------------------------------------------
    def _attn_kv_bytes(self, span: int) -> int:
        """Analytic attention-read bytes for one decode/verify step whose
        deepest read per active row is ``pos + span``: the fused kernel
        touches each row's live block-rounded chain; the reference gather
        materializes every row's whole [L_max] logical view."""
        bs = self.ecfg.kv_block_size
        if self._paged:
            if self.ecfg.fused_paged_attention:
                lens = self.pos[self.active] + span
                toks = int(np.sum(-(-lens // bs) * bs))
            else:
                toks = self.ecfg.max_slots * self.blocks_per_slot * bs
        else:
            toks = self.ecfg.max_slots * self.ecfg.max_seq_len
        return toks * self._kv_token_bytes

    def _prefill_kv_bytes(self, upto: int) -> int:
        """Analytic attention-read bytes for one prefill chunk whose
        deepest position is ``upto``: the q-tiled kernel's causal pruning
        stops at the slab-block-rounded write frontier; the chunked
        reference scans the whole scratch slab."""
        if self._paged and self.ecfg.fused_paged_attention:
            toks = -(-upto // self._slab_bs) * self._slab_bs
        else:
            toks = self._scratch_len
        return toks * self._kv_token_bytes

    # ------------------------------------------------------------------
    def _next_key(self, stream_key, idx: int):
        return self.core.next_key(stream_key, idx)

    def _prefill_work(self, now: float) -> bool:
        did = False
        C = self.ecfg.prefill_chunk
        for _ in range(self.ecfg.chunks_per_step):
            if self._pf is None:
                if not self._pf_queue:
                    break
                self._pf = self._pf_queue.popleft()
                # recurrent-state stores reset the scratch to the pristine
                # zero state here: chunked prefill *carries* state across
                # chunk calls (that is prefill continuation), so a new
                # request must not inherit the previous one's fold
                self.kv.begin_prefill()
            st = self._pf
            t0 = time.perf_counter()
            if self._sharing and st.prefill_pos > 0 and not st.prefix_loaded:
                # mid-prompt restart off a cached prefix: the uncached
                # tail's attention reads the prefix K/V from the scratch,
                # so gather it out of the shared blocks first
                with self._ctx():
                    self._scratch = self._gather_fn(
                        self.pool, self._scratch, self._bt_row(st),
                        np.int32(st.prefill_pos))
                st.prefix_loaded = True
            seq = st.prefill_tokens
            start, L = st.prefill_pos, st.prefill_len
            n = min(C, L - start)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :n] = seq[start:start + n]
            key = self._next_key(self._pf_key, self._chunk_idx)
            self._chunk_idx += 1
            with self._ctx():
                logits, self._scratch, _, diags = self._prefill_fn(
                    self.params, chunk, self._scratch, np.int32(start),
                    np.int32(n - 1), key, self._replica_ids)
                if self._paged:
                    # finished chunk -> straight into the allocated blocks
                    # (valid_to diverts ring-leaf pad writes to the null
                    # block so they cannot clobber in-window ring slots)
                    self.pool = self._write_fn(
                        self.pool, self._scratch, self._bt_row(st),
                        np.int32(start), np.int32(start + n))
                jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            st.prefill_pos += n
            if self._sharing:
                # every block fully covered by committed K/V joins the
                # prefix index (keyed on its token-id chain)
                self._alloc.commit_prefix(st.req.rid,
                                          seq[:st.prefill_pos])
            self.metrics.record_step(diags if self.cfg.is_moe else {}, 0,
                                     phase="prefill")
            # prefix-tail: the request restarted mid-sequence off a prefix
            # cache hit, so its chunks attend a deeper window than a plain
            # prefill of the same tail length
            self.metrics.record_phase(
                ("prefix_tail" if (self._sharing
                                   and (st.cached_prefix_tokens or 0) > 0)
                 else "prefill"),
                n, dt, self._prefill_kv_bytes(start + n))
            did = True
            if st.prefill_done:
                if st.resumed:
                    # recompute finished: the re-prefill rebuilt the state
                    # for prompt + output[:-1]; the pending last token
                    # decodes next step.  No TTFT restamp, no logits
                    # consumed.  Paged chains were written chunk-by-chunk
                    # above; a slab/slot store commits its rebuilt
                    # scratch state to the slot now — without this the
                    # resumed request would decode off the stale slot.
                    if not self._paged:
                        with self._ctx():
                            self.pool = self._write_fn(
                                self.pool, self._scratch, np.int32(st.slot))
                    self._activate(st, L, st.output[-1])
                    self._pf = None
                    continue
                first = sample_np(np.asarray(logits)[0], self._samp_rng,
                                  temperature=self.ecfg.temperature,
                                  top_k=self.ecfg.top_k,
                                  top_p=self.ecfg.top_p)
                if not self._paged:
                    with self._ctx():
                        self.pool = self._write_fn(self.pool, self._scratch,
                                                   np.int32(st.slot))
                # stamp AFTER the host sync: TTFT must include the prefill
                # compute, not just the queueing ahead of it
                now = self.clock.now()
                st.first_token_time = now
                st.output.append(first)
                eos = self._eos_id(st.req)
                if (eos is not None and first == eos) \
                        or st.n_generated >= st.req.max_new_tokens:
                    self._finish(st, now)
                elif self.role == "prefill":
                    # prefill role: the request leaves this engine here —
                    # the scratch still holds its full committed K/V, so
                    # export before the next chunk overwrites it
                    self._export_handoff(st)
                else:
                    self._activate(st, L, first)
                self._pf = None
        return did

    def _decode_work(self, now: float) -> bool:
        if self._spec:
            return self._speculative_decode_work(now)
        if self._paged and self.active.any():
            self._ensure_decode_blocks()
        if not self.active.any():
            return False
        self._apply_pending_stage()
        key = self._next_key(self._dec_key, self._step_idx)
        bt_args = (self.block_table.copy(),) if self._paged else ()
        t0 = time.perf_counter()
        with self._ctx():
            nxt, self.pool, diags = self._decode_fn(
                self.params, self.tok[:, None], self.pool, self.pos,
                *bt_args, key, self.active.copy(), self._replica_ids,
                self._residency_ids)
        nxt = np.asarray(nxt)
        dt = time.perf_counter() - t0
        now = self.clock.now()       # post-sync: token times include compute
        diags = dict(diags)
        layer_loads = diags.pop("expert_load_layers", None)
        self.metrics.record_step(diags if self.cfg.is_moe else {},
                                 int(self.active.sum()), phase="decode")
        self.metrics.record_phase("decode", int(self.active.sum()), dt,
                                  self._attn_kv_bytes(1))
        self._observe_load(diags)
        self._observe_residency(layer_loads)
        if self._paged:
            self.metrics.record_kv(self._alloc.blocks_in_use,
                                   self._alloc.usable_blocks)
        for s in np.nonzero(self.active)[0]:
            st = self.state_by_slot[s]
            self.pos[s] += 1
            t = int(nxt[s])
            st.output.append(t)
            if self._sharing and self.pos[s] % self.ecfg.kv_block_size == 0:
                # this step's write just filled a block: index it so later
                # prompts extending this sequence (e.g. multi-turn) can hit
                full = np.concatenate([st.req.tokens,
                                       np.asarray(st.output, np.int32)])
                self._alloc.commit_prefix(st.req.rid, full[:self.pos[s]])
            eos = self._eos_id(st.req)
            if (eos is not None and t == eos) \
                    or st.n_generated >= st.req.max_new_tokens:
                self._finish(st, now)
            else:
                self.tok[s] = t
        return True

    def _speculative_decode_work(self, now: float) -> bool:
        """One speculative decode step: draft up to k tokens per active
        slot (self-drafting, host-side), verify them all in one static
        ``[B, k + 1]`` forward against the paged pool, and commit the
        accepted prefix plus one token from the verify logits — between 1
        and k + 1 tokens per step.  Rejected window positions' K/V writes
        are rolled back by masking: they sit past the committed length
        (``pos`` never counts them), each is rewritten with real K/V
        before ``pos`` reaches it, and the CoW guard in
        ``_ensure_decode_blocks`` keeps them out of shared blocks — so
        sharing, preemption-by-recompute, and the prefix index all stay
        token-exact."""
        if self.active.any():
            self._ensure_decode_blocks()
        if not self.active.any():
            return False
        self._apply_pending_stage()
        B, k = self.ecfg.max_slots, self.ecfg.speculative_k
        bs = self.ecfg.kv_block_size
        toks = np.zeros((B, k + 1), np.int32)
        draft_len = np.zeros((B,), np.int32)
        for s in np.nonzero(self.active)[0]:
            st = self.state_by_slot[s]
            toks[s, 0] = self.tok[s]
            # never draft past the generation budget: the step commits up
            # to draft_len + 1 tokens, and max_new caps committed tokens
            cap = min(k, st.req.max_new_tokens - st.n_generated - 1)
            if cap > 0:
                ctx = np.concatenate([st.req.tokens,
                                      np.asarray(st.output, np.int32)])
                d = self._proposer.propose(ctx, cap)
                toks[s, 1:1 + len(d)] = d
                draft_len[s] = len(d)
        key = self._next_key(self._dec_key, self._step_idx)
        t0 = time.perf_counter()
        with self._ctx():
            logits, self.pool, diags = self._decode_fn(
                self.params, toks, self.pool, self.pos,
                self.block_table.copy(), key, self.active.copy(),
                self._replica_ids, self._residency_ids)
        logits = np.asarray(logits)          # [B, k+1, V]
        dt = time.perf_counter() - t0
        now = self.clock.now()   # post-sync: token times include compute
        diags = dict(diags)
        layer_loads = diags.pop("expert_load_layers", None)
        self.metrics.record_step(diags if self.cfg.is_moe else {},
                                 int(self.active.sum()), phase="decode")
        # bytes computed against pre-commit positions: the verify window
        # reads each active row's chain up to pos + k + 1
        verify_bytes = self._attn_kv_bytes(k + 1)
        self._observe_load(diags)
        self._observe_residency(layer_loads)
        self.metrics.record_kv(self._alloc.blocks_in_use,
                               self._alloc.usable_blocks)
        self.metrics.spec_steps += 1
        self.metrics.spec_slot_steps += int(self.active.sum())
        total_commit = 0
        for s in np.nonzero(self.active)[0]:
            st = self.state_by_slot[s]
            drafts = toks[s, 1:1 + int(draft_len[s])].tolist()
            if self._sample:
                n_acc, nxt = rejection_verify(
                    logits[s], drafts, self._samp_rng,
                    temperature=self.ecfg.temperature,
                    top_k=self.ecfg.top_k, top_p=self.ecfg.top_p)
            else:
                n_acc, nxt = greedy_verify(logits[s], drafts)
            self.metrics.spec_drafted += len(drafts)
            self.metrics.spec_accepted += n_acc
            old_pos = int(self.pos[s])
            eos = self._eos_id(st.req)
            finished = False
            n_commit = 0
            for t in drafts[:n_acc] + [nxt]:
                st.output.append(int(t))
                n_commit += 1
                if (eos is not None and t == eos) \
                        or st.n_generated >= st.req.max_new_tokens:
                    finished = True
                    break
            self.pos[s] += n_commit
            self.metrics.spec_committed += n_commit
            total_commit += n_commit
            if self._sharing and self.pos[s] // bs > old_pos // bs:
                # crossed >= 1 block boundary this step: index every newly
                # full block so later prompts can hit them
                full = np.concatenate([st.req.tokens,
                                       np.asarray(st.output, np.int32)])
                self._alloc.commit_prefix(st.req.rid, full[:self.pos[s]])
            if finished:
                self._finish(st, now)
            else:
                self.tok[s] = st.output[-1]
        self.metrics.record_phase("verify", total_commit, dt, verify_bytes)
        return True

    # ------------------------------------------------------------------
    # prefill→decode handoff (split engine roles; serve/kvstore.py)
    # ------------------------------------------------------------------
    def _export_handoff(self, st: RequestState) -> None:
        """Package a finished prefill as a ``HandoffRecord`` and release
        its slot + blocks.  The scratch cache still holds the request's
        full committed K/V (gathered cached prefix included), so the
        export is a pure host-side slice; indexed prefix blocks stay on
        the cached-free list, so the prefill side's prefix cache keeps
        serving later arrivals."""
        C = self.ecfg.prefill_chunk
        pad = round_up(st.prefill_len, C)
        rec = HandoffRecord(
            rid=st.req.rid, prompt_tokens=st.req.tokens.copy(),
            output=list(st.output), pos=st.prefill_len, pad_len=pad,
            prefill_chunk=C, max_new_tokens=st.req.max_new_tokens,
            eos_id=st.req.eos_id, kv=self.kv.export_kv(pad),
            cached_prefix_tokens=int(st.cached_prefix_tokens or 0),
            arrival_time=st.req.arrival_time,
            admitted_time=st.admitted_time,
            first_token_time=st.first_token_time)
        self._handoffs_out.append(rec)
        self.handoffs_exported += 1
        self.handoff_bytes_out += rec.nbytes
        st.status = RequestStatus.HANDED_OFF
        s = st.slot
        self.state_by_slot[s] = None
        self.free_slots.append(s)
        self._alloc.release(st.req.rid)
        self.block_table[s, :] = NULL_BLOCK

    def pop_handoffs(self) -> List[HandoffRecord]:
        """Drain the exported-handoff queue (prefill role; the fleet
        router moves these to a decode-role engine)."""
        out = list(self._handoffs_out)
        self._handoffs_out.clear()
        return out

    def import_handoff(self, rec: HandoffRecord) -> bool:
        """Adopt a handed-off request: allocate a slot + block chain,
        scatter the record's KV into this engine's pool, and join the
        decode batch at the exporter's committed position.  Returns False
        (record untouched, retry later) when no slot or not enough blocks
        are free right now; raises when the record can never fit this
        engine's shapes."""
        if not self._paged:
            raise RuntimeError("import_handoff needs the paged KV pool")
        C, bs = self.ecfg.prefill_chunk, self.ecfg.kv_block_size
        if rec.prefill_chunk != C:
            raise ValueError(
                f"handoff was prefilled with chunk {rec.prefill_chunk}, "
                f"this engine uses {C}; the import replays the exporter's "
                f"chunk-aligned scatters, so the two must match")
        L = len(rec.prompt_tokens)
        if L + rec.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"handoff {rec.rid}: prompt {L} + max_new "
                f"{rec.max_new_tokens} exceeds max_seq_len "
                f"{self.ecfg.max_seq_len}")
        if rec.pad_len > self.kv_capacity:
            raise ValueError(
                f"handoff {rec.rid}: padded prefill {rec.pad_len} exceeds "
                f"the per-layer KV capacity {self.kv_capacity}")
        if not self.free_slots:
            return False
        n_blocks = blocks_for_tokens(rec.pad_len, bs)
        if not self._alloc.can_allocate(n_blocks, []):
            return False
        req = Request(rid=rec.rid, tokens=rec.prompt_tokens,
                      max_new_tokens=rec.max_new_tokens,
                      arrival_time=rec.arrival_time, eos_id=rec.eos_id)
        st = RequestState(req=req, slot=-1,
                          admitted_time=rec.admitted_time,
                          first_token_time=rec.first_token_time,
                          output=list(rec.output), prefill_pos=rec.pos,
                          cached_prefix_tokens=rec.cached_prefix_tokens)
        slot = self.free_slots.popleft()
        st.slot = slot
        st.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.state_by_slot[slot] = st
        self.slot_history.append((req.rid, slot))
        chain = self._alloc.alloc_chain(req.rid, n_blocks)
        assert chain is not None          # gated by can_allocate above
        self.kv.import_kv(rec.kv, rec.pad_len, self.kv.bt_row(req.rid))
        if self._sharing:
            # the imported K/V is bit-identical to a local prefill's, so
            # its full blocks are index-worthy here too
            self._alloc.commit_prefix(req.rid,
                                      st.prefill_tokens[:rec.pos])
        self.handoffs_imported += 1
        self.handoff_bytes_in += rec.nbytes
        self._activate(st, rec.pos, st.output[-1])
        return True

    # ------------------------------------------------------------------
    # fleet routing probes (serve/fleet.py)
    # ------------------------------------------------------------------
    def load_stats(self) -> Dict[str, Any]:
        """Cheap scheduler-state snapshot the fleet router scores replicas
        by — no device sync, no allocator mutation."""
        if self._paged:
            bs = self.ecfg.kv_block_size
            kv_tokens = self._alloc.blocks_in_use * bs
            kv_util = (self._alloc.blocks_in_use
                       / max(self._alloc.usable_blocks, 1))
        else:
            kv_tokens = int(self.pos.sum())
            kv_util = float(self.active.sum()) / self.ecfg.max_slots
        return {
            "queued_tokens": self.front.queued_tokens(),
            "kv_tokens": int(kv_tokens),
            "kv_utilization": float(kv_util),
            "active_slots": int(self.active.sum()),
            "free_slots": len(self.free_slots),
            "pending_handoffs": len(self._handoffs_out),
        }

    def probe_prefix(self, tokens) -> int:
        """Longest cached-prefix match for ``tokens`` in this engine's
        prefix index, in tokens (0 without prefix sharing).  Pure lookup —
        probing a replica that is not chosen never perturbs its LRU."""
        return self.kv.probe_prefix(tokens)

    # ------------------------------------------------------------------
    # between-window hot-expert replication (serve/rebalance.py)
    # ------------------------------------------------------------------
    def _observe_load(self, diags) -> None:
        """Fold this decode step's global per-expert load into the
        rebalancer's EMA (the [Ep] ``expert_load`` vector the MoE layer
        emits alongside its scalar diagnostics)."""
        if self._rebalancer is None or "expert_load" not in diags:
            return
        self._rebalancer.observe(
            np.asarray(diags["expert_load"]).reshape(-1))

    def _rebalance_now(self) -> None:
        """Close a load window: re-derive the hot-expert set from the EMA
        and, if it changed, gather the hot experts' weight rows into every
        non-host rank's replica slots.  Pure value updates — the swap fn
        and the decode fn keep their single jit entries, and the new
        ``replica_ids`` flow into the next step as a traced argument."""
        dec = self._rebalancer.propose()
        self._rebalances += 1
        if not dec.changed:
            return
        with self._ctx():
            self.params = self._swap_fn(self.params, dec.weight_rows)
        self._replica_ids = dec.replica_ids
        self._replica_swaps += 1

    # ------------------------------------------------------------------
    # tiered expert residency (serve/residency.py)
    # ------------------------------------------------------------------
    def _observe_residency(self, layer_loads) -> None:
        """Feed this step's stacked per-layer expert loads (the
        ``expert_load_layers`` diagnostic, [n_moe_layers, Ep]) to the
        residency manager.  Its decision — new table + stage rows — is
        held as the *pending* stage and applied at the start of the next
        decode step, double-buffering the emulated host→HBM copy against
        that step's compute."""
        if self._residency is None or layer_loads is None:
            return
        self._pending_stage = self._residency.step(np.asarray(layer_loads))

    def _apply_pending_stage(self) -> None:
        """Apply the previous step's residency decision: dispatch its
        jitted staging scatter now (jax's async dispatch overlaps the
        copy with the decode compute that follows), then publish the new
        ``[G, W]`` table as this step's traced argument."""
        dec = self._pending_stage
        if dec is None:
            return
        self._pending_stage = None
        if dec.stage_rows.size:
            self._dispatch_stage(dec.stage_rows)
        self._residency_ids = dec.residency_ids

    def _dispatch_stage(self, rows: np.ndarray) -> None:
        """Run the host→HBM staging scatter for ``rows`` (stacked
        weight-row indices).  Rows are padded/clipped to the fixed stage
        width so the jit cache keeps one entry; padding repeats row 0,
        which is safe because every staged value is gathered from the
        host tier — a bit-identical copy of the device rows."""
        padded = np.zeros((self._stage_width,), np.int32)
        n = min(len(rows), self._stage_width)
        padded[:n] = rows[:n]
        vals = [np.take(h, padded, axis=h.ndim - 3)
                for h in self._host_tier]
        with self._ctx():
            self.params = self._stage_fn(self.params, padded, vals)
        self._residency_stages += 1

    def _finish(self, st: RequestState, now: float) -> None:
        st.finish_time = now
        st.status = RequestStatus.FINISHED
        self.metrics.complete(st)
        s = st.slot
        self.active[s] = False
        self.pos[s] = 0
        self.tok[s] = 0
        self.state_by_slot[s] = None
        self.free_slots.append(s)
        # immediate reclamation: paged blocks return to the free list now
        # (slab/slot stores drop nothing — the row is overwritten whole at
        # the next admission)
        self.kv.release(st.req.rid, s)

    # ------------------------------------------------------------------
    def reset_metrics(self) -> None:
        """Fresh metrics AND a re-zeroed clock for a new measurement window;
        slot state, jit caches, and warmup status are kept. Queued (not yet
        admitted) requests are fine — like ``run()``'s rebase, only their
        window-relative arrival times carry over — but admitted work holds
        timestamps on the current clock, so the engine must have nothing in
        flight."""
        if self._in_flight():
            raise RuntimeError("cannot reset metrics while work is in flight")
        self.metrics = ServeMetrics()
        self._register_sections()
        self.slot_history.clear()
        if self._paged:
            self._evict0 = self._alloc.evictions
            self._cow0 = self._alloc.cow_copies
        if self._residency is not None:
            self._res_base = self._residency.counters()
        self.clock.reset()

    def warmup(self) -> None:
        """Compile the jitted functions on dummy data so the first request's
        TTFT measures serving latency, not XLA compilation.  Overwrites pool
        slot 0 (slab) / the null block (paged) and the scratch cache, so the
        engine must be idle (enforced) — call before submitting work."""
        if self.has_work() or any(st is not None for st in self.state_by_slot):
            raise RuntimeError(
                "warmup() overwrites pool slot 0 and the scratch cache; it "
                "must run on an idle engine (no queued or in-flight "
                "requests, no occupied slots)")
        C = self.ecfg.prefill_chunk
        chunk = np.zeros((1, C), np.int32)
        # warmup traces every jitted entry exactly once per shape, so the
        # attention dispatch log captured around it is the engine's full
        # kernel-coverage map (fused vs reference per branch) — reset it
        # here so other engines' traces don't bleed in
        attention_dispatch.reset_dispatch_log()
        # two passes: the first compiles against the freshly-initialized
        # cache shardings, the second against jit's steady-state output
        # shardings (they can differ on multi-device meshes)
        for i in range(2):
            key = self._next_key(self._pf_key, 2 ** 31 - 1 - i)
            with self._ctx():
                _, self._scratch, _, _ = self._prefill_fn(
                    self.params, chunk, self._scratch, np.int32(0),
                    np.int32(C - 1), key, self._replica_ids)
                if self._paged:
                    # an all-null table row: every write lands in the
                    # null block's garbage
                    self.pool = self._write_fn(
                        self.pool, self._scratch,
                        np.full((self.blocks_per_slot,), NULL_BLOCK,
                                np.int32), np.int32(0), np.int32(C))
                else:
                    self.pool = self._write_fn(self.pool, self._scratch,
                                               np.int32(0))
                key = self._next_key(self._dec_key, 2 ** 31 - 1 - i)
                bt_args = ((np.full_like(self.block_table, NULL_BLOCK),)
                           if self._paged else ())
                # speculative: the decode entry is the [B, k+1] verify step
                warm_tok = (np.zeros((self.ecfg.max_slots,
                                      self.ecfg.speculative_k + 1), np.int32)
                            if self._spec else self.tok[:, None])
                nxt, self.pool, _ = self._decode_fn(
                    self.params, warm_tok, self.pool, self.pos,
                    *bt_args, key, self.active.copy(), self._replica_ids,
                    self._residency_ids)
                if self._paged and self._sharing:
                    # gather through an all-null row (masked to 0 tokens)
                    # and copy the null block onto itself: both compile
                    # against garbage nothing reads
                    self._scratch = self._gather_fn(
                        self.pool, self._scratch,
                        np.full((self.blocks_per_slot,), NULL_BLOCK,
                                np.int32), np.int32(0))
                    self.pool = self._copy_fn(self.pool,
                                              np.int32(NULL_BLOCK),
                                              np.int32(NULL_BLOCK))
            jax.block_until_ready(nxt)
        if self._rebalancer is not None:
            # compile the weight-swap gather too: replica slots are empty
            # (ids all -1) so the copied values are dead, and the real
            # swaps later must not show up as post-warmup compiles
            G, R = self._replica_ids.shape
            with self._ctx():
                self.params = self._swap_fn(
                    self.params, np.zeros((G * R,), np.int32))
        if self._residency is not None:
            # compile the staging scatter too: row-0 identity writes, so
            # real residency swaps never show up as post-warmup compiles
            self._dispatch_stage(np.zeros((0,), np.int32))
            self._residency_stages = 0
        # multi-device: the first call may trace twice while cache shardings
        # settle to jit's steady state; anything beyond this is a regression
        self._warm_counts = self._jit_counts()
        # snapshot the per-trace attention dispatch records: every branch
        # (prefill / prefill_continue / decode / verify) has now been traced
        # once per layer, so this is the engine's kernel-coverage map
        self._attn_dispatch = attention_dispatch.dispatch_log()

    def step(self, now: Optional[float] = None, *,
             wait_when_idle: bool = True) -> bool:
        """One scheduler tick: admit, prefill chunk(s), decode the batch.

        ``now`` lets a fleet router drive several replicas off one shared
        clock reading per tick (each engine-side ``clock.now()`` call
        advances a VirtualClock, so per-replica reads would skew time);
        ``wait_when_idle=False`` defers the idle wait to the router, which
        knows every replica's next arrival.  Returns whether any prefill
        or decode work ran."""
        if now is None:
            now = self.clock.now()
        self._admit(now)
        did = self._prefill_work(now)
        did = self._decode_work(now) or did
        self._step_idx += 1
        if self._rebalancer is not None \
                and self.ecfg.rebalance_interval > 0 \
                and self._step_idx % self.ecfg.rebalance_interval == 0 \
                and self._rebalancer.steps_observed > 0:
            self._rebalance_now()
        if not did and wait_when_idle:
            nxt = self.queue.next_arrival()
            if nxt is not None:
                self.clock.wait(min(max(nxt - now, 0.0), 0.01))
        return did

    def run(self, requests: Sequence[Request] = (), *,
            max_steps: int = 1_000_000) -> Dict[str, Any]:
        """Drive the engine until all work drains.

        At the start of a fresh measurement window — nothing in flight and
        no metrics recorded yet — the clock is rebased to 0 so that arrival
        times (which start at 0) are measured from this call, not from
        engine construction: warmup/compile time and prior windows' wall
        time stay out of TTFT/e2e/queue_delay, and open-loop Poisson
        arrivals stay in the future rather than all already arrived.
        Requests submitted via ``submit()`` before this call don't block
        the rebase (their arrival times are window-relative); in-flight
        work or already-recorded metrics do, since their timestamps live on
        the current timebase — accumulating several ``run()`` calls into
        one window therefore keeps one continuous clock, and the caller
        owns any arrival-time offsets for the later batches.
        """
        if not self._in_flight() and self.metrics.empty:
            self.clock.reset()
        for r in requests:
            self.submit(r)
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"serve engine exceeded {max_steps} steps "
                                   f"with work remaining")
        return self.report()

    def _register_sections(self) -> None:
        """Engine-owned report sections, attached through the metrics
        section convention (metrics.py) — re-registered whenever the
        metrics object is replaced (reset_metrics)."""
        self.metrics.register_section("state_pool", self._state_pool_section)

    def _state_pool_section(self) -> Dict[str, Any]:
        """The sequence-state store's report section: pool kind plus
        store-specific occupancy/counters (``SequenceStateStore.stats``),
        with the scheduler-side pressure counters that give them meaning."""
        sec = self.kv.stats()
        sec["preemptions"] = self.metrics.preemptions
        return sec

    def report(self) -> Dict[str, Any]:
        if self._paged:
            self.metrics.evictions = self._alloc.evictions - self._evict0
            self.metrics.cow_copies = self._alloc.cow_copies - self._cow0
        if self._residency is not None:
            # window counters: lifetime minus the reset_metrics snapshot
            cur = self._residency.counters()
            base = self._res_base or {}
            win = {k: cur[k] - base.get(k, 0)
                   for k in cur if k != "hit_rate"}
            win["hit_rate"] = (win["hits"] / win["lookups"]
                               if win["lookups"] else None)
            self.metrics.residency = win
        rep = self.metrics.report()
        rep["engine"] = {
            "max_slots": self.ecfg.max_slots,
            "max_seq_len": self.ecfg.max_seq_len,
            "prefill_chunk": self.ecfg.prefill_chunk,
            "kv_capacity": self.kv_capacity,
            "steps": self._step_idx,
            "paged": self._paged,
            "role": self.role,
        }
        if self._paged:
            rep["engine"]["kv_block_size"] = self.ecfg.kv_block_size
            rep["engine"]["num_kv_blocks"] = self._alloc.usable_blocks
            rep["engine"]["blocks_per_slot"] = self.blocks_per_slot
            rep["engine"]["prefix_sharing"] = self._sharing
            rep["engine"]["fused_paged_attention"] = \
                self.ecfg.fused_paged_attention
            rep["engine"]["speculative_k"] = self.ecfg.speculative_k
            if self._spec:
                rep["engine"]["speculative_policy"] = \
                    self.ecfg.speculative_policy
        if self.role != "unified" or self.handoffs_exported \
                or self.handoffs_imported:
            rep["engine"]["handoffs"] = {
                "exported": self.handoffs_exported,
                "imported": self.handoffs_imported,
                "bytes_out": self.handoff_bytes_out,
                "bytes_in": self.handoff_bytes_in,
                "pending": len(self._handoffs_out),
            }
        if self.cfg.is_moe:
            rep["engine"]["moe_policy"] = \
                self._moe_policy or self.cfg.moe.policy
            rep["engine"]["fused_moe_gmm"] = self.ecfg.fused_moe_gmm
            rep["engine"]["replica_slots"] = self.ecfg.replica_slots
            if self._rebalancer is not None:
                rep["engine"]["rebalance_interval"] = \
                    self.ecfg.rebalance_interval
                rep["engine"]["rebalances"] = self._rebalances
                rep["engine"]["replica_swaps"] = self._replica_swaps
                rep["engine"]["replica_ids"] = self._replica_ids.tolist()
                rep["engine"]["hot_experts"] = self._rebalancer.hot()
            rep["engine"]["resident_experts"] = self.ecfg.resident_experts
            if self._residency is not None:
                rep["engine"]["prefetch_policy"] = self.ecfg.prefetch_policy
                rep["engine"]["residency_stages"] = self._residency_stages
                rep["engine"]["residency_ids"] = \
                    self._residency_ids.tolist()
        snap = (self._attn_dispatch if self._attn_dispatch is not None
                else attention_dispatch.dispatch_log())
        if snap:
            # per-branch kernel coverage captured at warmup trace time: the
            # last record per branch wins (all traces of one branch agree)
            branches: Dict[str, Dict[str, Any]] = {}
            for d in snap:
                branches[d["branch"]] = {
                    "fused": d["fused"],
                    "requested": d["requested"],
                    "reason": d.get("reason", ""),
                }
            rep["attention_dispatch"] = branches
            rep["attention_fallbacks"] = \
                attention_dispatch.fallback_counts(snap)
        rep["jit_entries"] = self._jit_counts()
        if self._warm_counts is not None:
            rep["recompiled_after_warmup"] = \
                rep["jit_entries"] != self._warm_counts
        return rep

    def _jit_counts(self) -> Dict[str, int]:
        counts = {**self.core.jit_counts(), **self.kv.jit_counts()}
        if self._rebalancer is not None:
            counts["replica_swap"] = self._swap_fn._cache_size()
        if self._residency is not None:
            counts["residency_stage"] = self._stage_fn._cache_size()
        return counts


# ----------------------------------------------------------------------
_EXPERT_LEAF_NAMES = ("w_in", "w_out", "w_gate")


def _collect_expert_leaves(params) -> List:
    """Every MoE expert weight leaf, in the deterministic order
    ``_stage_resident_weights`` visits them.  MoE parameter dicts are
    the ones carrying a ``router`` — dense MLP blocks reuse the
    ``w_in``/``w_out`` names but have no router."""
    out: List = []

    def walk(tree):
        if isinstance(tree, dict):
            if "router" in tree and "w_in" in tree:
                for name in _EXPERT_LEAF_NAMES:
                    if name in tree:
                        out.append(tree[name])
                return
            for v in tree.values():
                walk(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v)
    walk(params)
    return out


def _stage_resident_weights(params, rows, vals):
    """Scatter staged expert rows into every expert weight leaf.

    ``rows`` [n] indexes the rank-major stacked expert-row axis (the
    same layout ``_swap_replica_weights`` gathers from); ``vals`` is the
    flat list of gathered host-tier slices in ``_collect_expert_leaves``
    order.  The writes are value-identity (the emulated host tier is a
    bit-exact copy of the device rows) — what's real is the dispatched
    copy whose bytes the residency cost model prices.  Shapes never
    change, so the jit cache holds one entry across all swaps."""
    it = iter(vals)

    def walk(tree):
        if isinstance(tree, dict):
            if "router" in tree and "w_in" in tree:
                out = dict(tree)
                for name in _EXPERT_LEAF_NAMES:
                    if name in tree:
                        out[name] = stage_expert_rows(tree[name], rows,
                                                      next(it))
                return out
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree
    return walk(params)


def _swap_replica_weights(params, rows):
    """Gather expert weight rows into every replica leaf of the parameter
    tree.  ``rows`` [G*R] indexes the rank-major stacked expert-row axis
    (``row = host_rank * experts_per_rank + local_slot``, the layout
    ``init_moe_params`` documents); each MoE parameter dict carries both
    the ``w_*`` source rows and the ``w_rep_*`` destination slots, so the
    swap is a pure per-leaf ``jnp.take`` — shapes (and therefore the jit
    cache) never change.  Works on stacked ([n_steps, rows, d, f]) and
    plain ([rows, d, f]) leaves alike: the row axis is always third from
    the end."""
    def walk(tree):
        if isinstance(tree, dict):
            if "w_rep_in" in tree and "w_in" in tree:
                out = dict(tree)
                for rep_name, src_name in (("w_rep_in", "w_in"),
                                           ("w_rep_out", "w_out"),
                                           ("w_rep_gate", "w_gate")):
                    if rep_name in tree:
                        w = tree[src_name]
                        out[rep_name] = jnp.take(w, rows, axis=w.ndim - 3)
                return out
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree
    return walk(params)


def engine_config_for(cfg, *, max_slots: int, prompt_len: int,
                      max_new_tokens: int, prefill_chunk: int = 0,
                      eos_id: Optional[int] = None,
                      skew_seed: int = 0, role: str = "unified",
                      paged: bool = False,
                      kv_block_size: int = 16, num_kv_blocks: int = 0,
                      prefix_sharing: bool = False,
                      fused_paged_attention: bool = False,
                      fused_moe_gmm: bool = False,
                      speculative_k: int = 0,
                      speculative_policy: str = "ngram",
                      temperature: float = 0.0,
                      top_k: int = 0, top_p: float = 1.0,
                      moe_policy: Optional[str] = None,
                      rebalance_interval: int = 0,
                      replica_slots: int = 0,
                      resident_experts: int = 0,
                      prefetch_policy: str = "predictive") -> EngineConfig:
    """Derive serving shapes from a workload: pool length covers prompt +
    generation, the prefill chunk divides the (padded) prompt, and the
    padded prompt fits every layer's KV capacity (sliding-window layers
    clamp their *slab* cache to the window; the paged pool serves them as
    ring buffers instead, so only the chunk-vs-ring bound applies there).
    Model-independent legality lives in ``EngineConfig.validate()``,
    which the returned config has already passed."""
    chunk = prefill_chunk or min(max(prompt_len, 1), 32)
    window = cfg.sliding_window or 0
    pad = round_up(prompt_len, chunk)
    if window and not paged and pad > window:
        # slab prefill writes into the window-clamped scratch; the paged
        # pool has no such limit (windowed leaves wrap a ring of
        # round_up(window, kv_block_size) positions — see kvstore.py)
        raise ValueError(
            f"padded prompt {pad} exceeds the sliding window {window}; "
            f"slab chunked prefill must fit the window-clamped KV cache "
            f"(the paged ring buffer lifts this — pass paged=True)")
    if window and paged and chunk > round_up(window, kv_block_size):
        raise ValueError(
            f"prefill_chunk {chunk} exceeds the sliding-window ring of "
            f"{round_up(window, kv_block_size)} tokens; one chunk must "
            f"never self-overlap a ring slot — shrink prefill_chunk")
    max_seq = max(prompt_len + max_new_tokens, pad)
    return EngineConfig(
        max_slots=max_slots,
        max_seq_len=max_seq,
        prefill_chunk=chunk, eos_id=eos_id, skew_seed=skew_seed,
        role=role,
        paged=paged, kv_block_size=kv_block_size,
        num_kv_blocks=num_kv_blocks, prefix_sharing=prefix_sharing,
        fused_paged_attention=fused_paged_attention,
        fused_moe_gmm=fused_moe_gmm,
        speculative_k=speculative_k,
        speculative_policy=speculative_policy,
        temperature=temperature, top_k=top_k, top_p=top_p,
        moe_policy=moe_policy, rebalance_interval=rebalance_interval,
        replica_slots=replica_slots,
        resident_experts=resident_experts,
        prefetch_policy=prefetch_policy)
