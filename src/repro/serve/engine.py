"""Continuous-batching serving engine.

The engine owns a static-shape slot pool (``model.init_cache`` at batch
``max_slots``) and drives two jitted functions with fixed signatures:

* ``model.prefill_chunk`` on a ``[1, prefill_chunk]`` scratch cache —
  newcomers' prompts are consumed chunk-by-chunk, interleaved with decode
  steps, then scattered into their slot (traced slot index);
* ``model.decode_step`` on the full pool with a per-slot position vector —
  every occupied slot advances one token per step regardless of how long
  each sequence already is.

Because every array shape is fixed at engine construction, the jit caches
hold exactly one entry each across admissions, slot recycling, and EOS —
``report()["jit_entries"]`` asserts this is so.

Requests enter through an ``AdmissionQueue`` (Poisson or trace-driven
arrivals); freed slots are immediately re-admitted from the queue. Per-step
MoE schedule diagnostics (moved_units, drops, max_load) and per-request
TTFT/TPOT/e2e flow into ``ServeMetrics``.

Scope (v1): decoder-only transformer families (dense and MoE); the mesh may
shard the model/expert axis but not the batch axis. SSM/hybrid state
caches, encoder-decoder, and prefix-embedding models are follow-ons.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import round_up
from repro.serve.arrivals import AdmissionQueue, WallClock
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.slots import (discover_batch_axes, discover_seq_axes,
                               min_kv_capacity, write_slot)


@dataclass(frozen=True)
class EngineConfig:
    """Static serving shapes — these fix every jitted signature."""
    max_slots: int = 4          # decode batch width (concurrent requests)
    max_seq_len: int = 128      # KV pool length (prompt + generation)
    prefill_chunk: int = 32     # prompt tokens consumed per prefill call
    chunks_per_step: int = 1    # prefill chunks interleaved per engine step
    eos_id: Optional[int] = None
    skew_seed: int = 0          # synthetic router-skew key stream


class ServeEngine:
    def __init__(self, model, params, ecfg: EngineConfig, *, mesh=None,
                 clock=None):
        cfg = model.cfg
        if cfg.family in ("ssm", "hybrid") or cfg.is_encoder_decoder \
                or cfg.num_prefix_embeddings:
            raise NotImplementedError(
                f"serve engine v1 supports decoder-only transformer "
                f"families; got {cfg.name} ({cfg.family})")
        extra = 1
        for ax, n in model.mesh_shape.sizes.items():
            if ax != "model":
                extra *= n
        if extra > 1:
            raise NotImplementedError(
                "serve engine v1 shards the model/expert axis only; run "
                "with data=1 (data-parallel serving is an open item)")
        if ecfg.prefill_chunk < 1 or ecfg.max_slots < 1 \
                or ecfg.chunks_per_step < 1:
            raise ValueError(
                "prefill_chunk, max_slots, and chunks_per_step must be >= 1")

        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.cfg = cfg
        self.mesh = mesh
        self.clock = clock or WallClock()
        self.metrics = ServeMetrics()

        self._skew = bool(cfg.is_moe and cfg.moe.router_skew > 0)
        self._base_key = jax.random.PRNGKey(ecfg.skew_seed)
        self._pf_key = jax.random.fold_in(self._base_key, 0)
        self._dec_key = jax.random.fold_in(self._base_key, 1)

        self._batch_axes = discover_batch_axes(model.init_cache,
                                               ecfg.max_seq_len)
        self._seq_axes = discover_seq_axes(model.init_cache,
                                           ecfg.max_seq_len)
        self.kv_capacity = min_kv_capacity(model.init_cache, ecfg.max_seq_len,
                                           self._seq_axes)
        with self._ctx():
            self.pool = model.init_cache(ecfg.max_slots, ecfg.max_seq_len)
            self._scratch = model.init_cache(1, ecfg.max_seq_len)

        self._prefill_fn = jax.jit(model.prefill_chunk)
        self._decode_fn = jax.jit(self._decode_impl)
        self._write_fn = jax.jit(
            lambda pool, scratch, slot: write_slot(pool, scratch, slot,
                                                   self._batch_axes))

        B = ecfg.max_slots
        self.pos = np.zeros((B,), np.int32)      # per-slot sequence length
        self.tok = np.zeros((B,), np.int32)      # per-slot last token
        self.active = np.zeros((B,), bool)       # slot in the decode batch
        self.state_by_slot: List[Optional[RequestState]] = [None] * B
        self.free_slots: deque = deque(range(B))
        self.queue = AdmissionQueue()
        self._pf: Optional[RequestState] = None      # prefill in flight
        self._pf_queue: deque = deque()              # slot reserved, waiting
        self.slot_history: List[Tuple[int, int]] = []  # (rid, slot) admits
        self._step_idx = 0
        self._chunk_idx = 0
        self._warm_counts: Optional[Dict[str, int]] = None

    # ------------------------------------------------------------------
    def _ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _decode_impl(self, params, tok, pool, pos, key, active):
        logits, pool, _, diags = self.model.decode_step(
            params, tok, pool, pos, skew_key=key, active_mask=active)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return nxt, pool, diags

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        L, C = req.prompt_len, self.ecfg.prefill_chunk
        if round_up(L, C) > self.kv_capacity:
            raise ValueError(
                f"request {req.rid}: prompt of {L} (padded to "
                f"{round_up(L, C)}) exceeds the per-layer KV capacity "
                f"{self.kv_capacity}")
        if L + req.max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {L} + max_new "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.ecfg.max_seq_len}")
        self.queue.push(req)

    def has_work(self) -> bool:
        return bool(len(self.queue) or self._in_flight())

    def _in_flight(self) -> bool:
        """Admitted work whose timestamps already live on the current clock
        (queued-but-unadmitted requests carry none — their arrival_time is
        relative to the measurement window, not the clock origin)."""
        return bool(self._pf is not None or self._pf_queue
                    or self.active.any())

    # ------------------------------------------------------------------
    def _admit(self, now: float) -> None:
        while self.free_slots:
            req = self.queue.pop_ready(now)
            if req is None:
                return
            slot = self.free_slots.popleft()
            st = RequestState(req=req, slot=slot, admitted_time=now)
            self.state_by_slot[slot] = st
            self.slot_history.append((req.rid, slot))
            self._pf_queue.append(st)

    def _next_key(self, stream_key, idx: int):
        if not self._skew:
            return None
        return jax.random.fold_in(stream_key, idx)

    def _prefill_work(self, now: float) -> bool:
        did = False
        C = self.ecfg.prefill_chunk
        for _ in range(self.ecfg.chunks_per_step):
            if self._pf is None:
                if not self._pf_queue:
                    break
                self._pf = self._pf_queue.popleft()
            st = self._pf
            start, L = st.prefill_pos, st.req.prompt_len
            n = min(C, L - start)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :n] = st.req.tokens[start:start + n]
            key = self._next_key(self._pf_key, self._chunk_idx)
            self._chunk_idx += 1
            with self._ctx():
                logits, self._scratch, _, diags = self._prefill_fn(
                    self.params, chunk, self._scratch, np.int32(start),
                    np.int32(n - 1), key)
            st.prefill_pos += n
            self.metrics.record_step(diags if self.cfg.is_moe else {}, 0,
                                     phase="prefill")
            did = True
            if st.prefill_done:
                first = int(np.argmax(np.asarray(logits)[0]))
                with self._ctx():
                    self.pool = self._write_fn(self.pool, self._scratch,
                                               np.int32(st.slot))
                # stamp AFTER the host sync: TTFT must include the prefill
                # compute, not just the queueing ahead of it
                now = self.clock.now()
                st.first_token_time = now
                st.output.append(first)
                eos = st.req.eos_id if st.req.eos_id is not None \
                    else self.ecfg.eos_id
                if (eos is not None and first == eos) \
                        or st.req.max_new_tokens == 1:
                    self._finish(st, now)
                else:
                    st.status = RequestStatus.DECODE
                    self.pos[st.slot] = L
                    self.tok[st.slot] = first
                    self.active[st.slot] = True
                self._pf = None
        return did

    def _decode_work(self, now: float) -> bool:
        if not self.active.any():
            return False
        key = self._next_key(self._dec_key, self._step_idx)
        with self._ctx():
            nxt, self.pool, diags = self._decode_fn(
                self.params, self.tok[:, None], self.pool, self.pos, key,
                self.active.copy())
        nxt = np.asarray(nxt)
        now = self.clock.now()       # post-sync: token times include compute
        self.metrics.record_step(diags if self.cfg.is_moe else {},
                                 int(self.active.sum()), phase="decode")
        for s in np.nonzero(self.active)[0]:
            st = self.state_by_slot[s]
            self.pos[s] += 1
            t = int(nxt[s])
            st.output.append(t)
            eos = st.req.eos_id if st.req.eos_id is not None \
                else self.ecfg.eos_id
            if (eos is not None and t == eos) \
                    or st.n_generated >= st.req.max_new_tokens:
                self._finish(st, now)
            else:
                self.tok[s] = t
        return True

    def _finish(self, st: RequestState, now: float) -> None:
        st.finish_time = now
        st.status = RequestStatus.FINISHED
        self.metrics.complete(st)
        s = st.slot
        self.active[s] = False
        self.pos[s] = 0
        self.tok[s] = 0
        self.state_by_slot[s] = None
        self.free_slots.append(s)

    # ------------------------------------------------------------------
    def reset_metrics(self) -> None:
        """Fresh metrics AND a re-zeroed clock for a new measurement window;
        slot state, jit caches, and warmup status are kept. Queued (not yet
        admitted) requests are fine — like ``run()``'s rebase, only their
        window-relative arrival times carry over — but admitted work holds
        timestamps on the current clock, so the engine must have nothing in
        flight."""
        if self._in_flight():
            raise RuntimeError("cannot reset metrics while work is in flight")
        self.metrics = ServeMetrics()
        self.slot_history.clear()
        self.clock.reset()

    def warmup(self) -> None:
        """Compile the three jitted functions on dummy data so the first
        request's TTFT measures serving latency, not XLA compilation.
        Overwrites pool slot 0 and the scratch cache, so the engine must
        be idle (enforced) — call before submitting work."""
        if self.has_work() or any(st is not None for st in self.state_by_slot):
            raise RuntimeError(
                "warmup() overwrites pool slot 0 and the scratch cache; it "
                "must run on an idle engine (no queued or in-flight "
                "requests, no occupied slots)")
        C = self.ecfg.prefill_chunk
        chunk = np.zeros((1, C), np.int32)
        # two passes: the first compiles against the freshly-initialized
        # cache shardings, the second against jit's steady-state output
        # shardings (they can differ on multi-device meshes)
        for i in range(2):
            key = self._next_key(self._pf_key, 2 ** 31 - 1 - i)
            with self._ctx():
                _, self._scratch, _, _ = self._prefill_fn(
                    self.params, chunk, self._scratch, np.int32(0),
                    np.int32(C - 1), key)
                self.pool = self._write_fn(self.pool, self._scratch,
                                           np.int32(0))
                key = self._next_key(self._dec_key, 2 ** 31 - 1 - i)
                nxt, self.pool, _ = self._decode_fn(
                    self.params, self.tok[:, None], self.pool, self.pos, key,
                    self.active.copy())
            jax.block_until_ready(nxt)
        # multi-device: the first call may trace twice while cache shardings
        # settle to jit's steady state; anything beyond this is a regression
        self._warm_counts = self._jit_counts()

    def step(self) -> None:
        """One scheduler tick: admit, prefill chunk(s), decode the batch."""
        now = self.clock.now()
        self._admit(now)
        did = self._prefill_work(now)
        did = self._decode_work(now) or did
        self._step_idx += 1
        if not did:
            nxt = self.queue.next_arrival()
            if nxt is not None:
                self.clock.wait(min(max(nxt - now, 0.0), 0.01))

    def run(self, requests: Sequence[Request] = (), *,
            max_steps: int = 1_000_000) -> Dict[str, Any]:
        """Drive the engine until all work drains.

        At the start of a fresh measurement window — nothing in flight and
        no metrics recorded yet — the clock is rebased to 0 so that arrival
        times (which start at 0) are measured from this call, not from
        engine construction: warmup/compile time and prior windows' wall
        time stay out of TTFT/e2e/queue_delay, and open-loop Poisson
        arrivals stay in the future rather than all already arrived.
        Requests submitted via ``submit()`` before this call don't block
        the rebase (their arrival times are window-relative); in-flight
        work or already-recorded metrics do, since their timestamps live on
        the current timebase — accumulating several ``run()`` calls into
        one window therefore keeps one continuous clock, and the caller
        owns any arrival-time offsets for the later batches.
        """
        if not self._in_flight() and self.metrics.empty:
            self.clock.reset()
        for r in requests:
            self.submit(r)
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"serve engine exceeded {max_steps} steps "
                                   f"with work remaining")
        return self.report()

    def report(self) -> Dict[str, Any]:
        rep = self.metrics.report()
        rep["engine"] = {
            "max_slots": self.ecfg.max_slots,
            "max_seq_len": self.ecfg.max_seq_len,
            "prefill_chunk": self.ecfg.prefill_chunk,
            "kv_capacity": self.kv_capacity,
            "steps": self._step_idx,
        }
        rep["jit_entries"] = self._jit_counts()
        if self._warm_counts is not None:
            rep["recompiled_after_warmup"] = \
                rep["jit_entries"] != self._warm_counts
        return rep

    def _jit_counts(self) -> Dict[str, int]:
        return {
            "prefill_chunk": self._prefill_fn._cache_size(),
            "decode": self._decode_fn._cache_size(),
            "write_slot": self._write_fn._cache_size(),
        }


# ----------------------------------------------------------------------
def engine_config_for(cfg, *, max_slots: int, prompt_len: int,
                      max_new_tokens: int, prefill_chunk: int = 0,
                      eos_id: Optional[int] = None,
                      skew_seed: int = 0) -> EngineConfig:
    """Derive serving shapes from a workload: pool length covers prompt +
    generation, the prefill chunk divides the (padded) prompt, and the
    padded prompt fits every layer's KV capacity (sliding-window layers
    clamp their cache to the window)."""
    chunk = prefill_chunk or min(max(prompt_len, 1), 32)
    window = cfg.sliding_window or 0
    pad = round_up(prompt_len, chunk)
    if window and pad > window:
        raise ValueError(
            f"padded prompt {pad} exceeds the sliding window {window}; "
            f"chunked prefill must fit the window-clamped KV cache")
    return EngineConfig(
        max_slots=max_slots,
        max_seq_len=max(prompt_len + max_new_tokens, pad),
        prefill_chunk=chunk, eos_id=eos_id, skew_seed=skew_seed)
