"""Request abstractions for the continuous-batching serving engine.

A ``Request`` is what a client submits: prompt tokens plus generation
limits and an arrival time (assigned by the arrival process). The engine
wraps each admitted request in a ``RequestState`` that tracks its slot,
progress, and the timestamps the metrics layer turns into TTFT/TPOT.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"        # waiting for a free slot
    PREFILL = "prefill"      # slot reserved, prompt chunks being consumed
    DECODE = "decode"        # in the decode batch, emitting tokens
    FINISHED = "finished"    # EOS or max_new_tokens reached
    HANDED_OFF = "handed_off"  # prefill-role engine exported the KV +
    #                            first token; a decode-role engine owns
    #                            the request from here


@dataclass
class Request:
    """One generation request.

    ``tokens`` is the prompt as int32 token ids; ``max_new_tokens`` bounds
    generation (the first token produced by prefill counts toward it);
    ``arrival_time`` is seconds on the engine clock (0 = already waiting).
    """
    rid: int
    tokens: np.ndarray
    max_new_tokens: int = 16
    arrival_time: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class RequestState:
    """Engine-side bookkeeping for one admitted request.

    A state preempted by the paged engine (its KV blocks reclaimed) goes
    back to the scheduler and is later *recomputed*: prefill re-runs over
    the prompt plus every committed output token except the last, whose
    K/V was never written — ``prefill_tokens`` is exactly that sequence.
    For a fresh request (no output yet) it degenerates to the prompt.
    """
    req: Request
    slot: int
    status: RequestStatus = RequestStatus.PREFILL
    prefill_pos: int = 0                 # prefill tokens consumed so far
    output: List[int] = field(default_factory=list)
    n_preempted: int = 0                 # times evicted for recompute
    admit_seq: int = 0                   # admission order (preemption age)
    # --- prefix sharing ---
    cached_prefix_tokens: Optional[int] = None  # prefill skipped at first
    #                                             admission via a cache hit
    prefix_loaded: bool = False          # cached prefix gathered to scratch
    # --- timestamps on the engine clock ---
    admitted_time: float = 0.0           # slot reserved / prefill started
    first_token_time: float = 0.0        # last prefill chunk done (TTFT point)
    finish_time: float = 0.0

    @property
    def n_generated(self) -> int:
        return len(self.output)

    @property
    def resumed(self) -> bool:
        """Re-admitted after preemption: decode state must be rebuilt."""
        return bool(self.output)

    @property
    def prefill_tokens(self) -> np.ndarray:
        """Token sequence the (re)prefill consumes."""
        if not self.output:
            return self.req.tokens
        return np.concatenate([self.req.tokens,
                               np.asarray(self.output[:-1], np.int32)])

    @property
    def prefill_len(self) -> int:
        return self.req.prompt_len + max(self.n_generated - 1, 0)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.prefill_len
