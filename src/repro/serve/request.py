"""Request abstractions for the continuous-batching serving engine.

A ``Request`` is what a client submits: prompt tokens plus generation
limits and an arrival time (assigned by the arrival process). The engine
wraps each admitted request in a ``RequestState`` that tracks its slot,
progress, and the timestamps the metrics layer turns into TTFT/TPOT.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"        # waiting for a free slot
    PREFILL = "prefill"      # slot reserved, prompt chunks being consumed
    DECODE = "decode"        # in the decode batch, emitting tokens
    FINISHED = "finished"    # EOS or max_new_tokens reached


@dataclass
class Request:
    """One generation request.

    ``tokens`` is the prompt as int32 token ids; ``max_new_tokens`` bounds
    generation (the first token produced by prefill counts toward it);
    ``arrival_time`` is seconds on the engine clock (0 = already waiting).
    """
    rid: int
    tokens: np.ndarray
    max_new_tokens: int = 16
    arrival_time: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class RequestState:
    """Engine-side bookkeeping for one admitted request."""
    req: Request
    slot: int
    status: RequestStatus = RequestStatus.PREFILL
    prefill_pos: int = 0                 # prompt tokens consumed so far
    output: List[int] = field(default_factory=list)
    # --- timestamps on the engine clock ---
    admitted_time: float = 0.0           # slot reserved / prefill started
    first_token_time: float = 0.0        # last prefill chunk done (TTFT point)
    finish_time: float = 0.0

    @property
    def n_generated(self) -> int:
        return len(self.output)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.req.prompt_len
