"""Fleet serving: a router over N ``ServeEngine`` replicas.

``FleetRouter`` owns one global arrival queue and a set of engine
replicas — real deployments give each replica a disjoint device group;
tests run *virtual* replicas (several engines on one group, each with its
own pool) — and drives them in lockstep off **one shared clock**: each
``tick()`` reads the clock once, routes every already-arrived request to
a replica, then steps all replicas at that same timestamp
(``ServeEngine.step(now, wait_when_idle=False)``).  With a single
replica this reduces exactly to the bare engine loop — same clock-call
count, same admission order, same idle waits — so greedy token streams
and timestamps are bit-identical (the fleet tests assert this).

Routing policies (``ROUTING_POLICIES``):

* ``load`` — send each arrival to the replica with the least committed
  work: queued prefill tokens (``AdmissionFront.queued_tokens``) plus KV
  tokens in use.  Ties break to the lowest replica index.
* ``prefix_affinity`` — the load score minus ``affinity_weight`` × the
  longest cached-prefix match probed across every replica's prefix index
  (``ServeEngine.probe_prefix`` — a pure lookup that never perturbs a
  probed-but-not-chosen replica's LRU).  Requests sharing a system
  prompt / few-shot template land where their prefix is already cached,
  so each replica's finite prefix cache stays warm for *its* prefix
  groups instead of thrashing across all of them.
* ``round_robin`` — arrival order modulo replica count (baseline).

An explicit ``assignment`` dict (rid → replica index) overrides the
policy per request — replaying one policy's recorded decisions under
another is how the tests pin down that routing only *places* work and
never changes what any replica computes.

**Disaggregated mode** pairs ``prefill``-role and ``decode``-role
engines: arrivals are routed among the prefill replicas, each finished
prefill surfaces as a ``HandoffRecord`` (``pop_handoffs``), and the
router moves it to the least-loaded decode replica
(``import_handoff`` — a False return means no slot/blocks free right
now; the record waits in FIFO order and is retried every tick).  Decode
replicas never see prompt traffic, so a burst of long prompts cannot
stall in-flight decodes — the regime the BENCH_serve fleet section
measures.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional, Sequence

from repro.serve.arrivals import AdmissionQueue
from repro.serve.engine import ServeEngine
from repro.serve.kvstore import HandoffRecord
from repro.serve.metrics import aggregate_fleet
from repro.serve.metrics import section as metrics_section
from repro.serve.request import Request

ROUTING_POLICIES = ("load", "prefix_affinity", "round_robin")


class FleetRouter:
    def __init__(self, engines: Sequence[ServeEngine], *,
                 policy: str = "load", affinity_weight: float = 1.0,
                 assignment: Optional[Dict[int, int]] = None):
        if not engines:
            raise ValueError("FleetRouter needs at least one engine")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; choose "
                             f"one of {ROUTING_POLICIES}")
        if affinity_weight < 0:
            raise ValueError("affinity_weight must be >= 0")
        self.engines = list(engines)
        self.clock = self.engines[0].clock
        for i, e in enumerate(self.engines):
            if e.clock is not self.clock:
                raise ValueError(
                    f"replica {i} has its own clock; fleet timestamps are "
                    f"only comparable when every engine shares one clock "
                    f"object")
        # arrivals go to engines that can prefill; handoffs to decode-role
        self._serve_idx = [i for i, e in enumerate(self.engines)
                           if e.role in ("unified", "prefill")]
        self._decode_idx = [i for i, e in enumerate(self.engines)
                            if e.role == "decode"]
        self._prefill_idx = [i for i, e in enumerate(self.engines)
                             if e.role == "prefill"]
        if not self._serve_idx:
            raise ValueError("fleet has no unified/prefill engine to "
                             "take arrivals")
        if self._prefill_idx and not self._decode_idx:
            raise ValueError("fleet has prefill-role engines but no "
                             "decode-role engine to hand off to")
        self.disaggregated = bool(self._prefill_idx)
        self.policy = policy
        self.affinity_weight = affinity_weight
        self.assignment = dict(assignment or {})

        self.queue = AdmissionQueue()
        self._pending: deque = deque()   # handoffs awaiting a free slot
        self._rr = 0                     # round-robin cursor
        self._decisions: List[Dict[str, Any]] = []
        self._routed_counts = [0] * len(self.engines)
        self._affinity_hits = 0          # routed where chosen match > 0
        self._affinity_hit_tokens = 0
        self._handoffs_moved = 0
        self._handoff_bytes = 0
        self._ticks = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.push(req)

    def has_work(self) -> bool:
        return bool(len(self.queue) or self._pending
                    or any(e.has_work() for e in self.engines))

    def warmup(self) -> None:
        for e in self.engines:
            e.warmup()

    # ------------------------------------------------------------------
    def _load_score(self, idx: int) -> float:
        stats = self.engines[idx].load_stats()
        return float(stats["queued_tokens"] + stats["kv_tokens"])

    def _route(self, req: Request) -> int:
        """Pick the replica for one arrival and record the decision."""
        matched = 0
        if req.rid in self.assignment:
            idx = self.assignment[req.rid]
            how = "assignment"
        elif self.policy == "round_robin":
            idx = self._serve_idx[self._rr % len(self._serve_idx)]
            self._rr += 1
            how = "round_robin"
        else:
            best = None
            for i in self._serve_idx:
                score = self._load_score(i)
                match = 0
                if self.policy == "prefix_affinity":
                    match = self.engines[i].probe_prefix(req.tokens)
                    score -= self.affinity_weight * match
                # strict < : ties break to the lowest replica index
                if best is None or score < best[0]:
                    best = (score, i, match)
            _, idx, matched = best
            how = self.policy
            if matched > 0:
                self._affinity_hits += 1
                self._affinity_hit_tokens += matched
        self._decisions.append({"rid": req.rid, "replica": idx,
                                "policy": how,
                                "matched_tokens": int(matched)})
        self._routed_counts[idx] += 1
        return idx

    def _move_handoffs(self) -> bool:
        """Collect every prefill replica's exported records and import
        each into the least-loaded decode replica; records that fit
        nowhere right now stay queued in FIFO order."""
        for i in self._prefill_idx:
            self._pending.extend(self.engines[i].pop_handoffs())
        moved = False
        still: deque = deque()
        while self._pending:
            rec: HandoffRecord = self._pending.popleft()
            order = sorted(self._decode_idx, key=lambda i:
                           (self._load_score(i), i))
            for i in order:
                if self.engines[i].import_handoff(rec):
                    self._handoffs_moved += 1
                    self._handoff_bytes += rec.nbytes
                    moved = True
                    break
            else:
                still.append(rec)
        self._pending = still
        return moved

    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One fleet scheduler tick: route ready arrivals, step every
        replica at one shared timestamp, move handoffs, then (if nothing
        ran anywhere) wait toward the earliest next arrival."""
        now = self.clock.now()
        while True:
            req = self.queue.pop_ready(now)
            if req is None:
                break
            self.engines[self._route(req)].submit(req)
        did = False
        for e in self.engines:
            did = e.step(now, wait_when_idle=False) or did
        if self.disaggregated:
            did = self._move_handoffs() or did
        self._ticks += 1
        if not did:
            heads = [self.queue.next_arrival()] \
                + [e.queue.next_arrival() for e in self.engines]
            heads = [h for h in heads if h is not None]
            if heads:
                self.clock.wait(min(max(min(heads) - now, 0.0), 0.01))
        return did

    def run(self, requests: Sequence[Request] = (), *,
            max_ticks: int = 1_000_000) -> Dict[str, Any]:
        """Drive the fleet until all work drains (mirrors
        ``ServeEngine.run``, including the fresh-window clock rebase)."""
        if not self._pending \
                and all(not e._in_flight() and e.metrics.empty
                        for e in self.engines):
            self.clock.reset()
        for r in requests:
            self.submit(r)
        ticks = 0
        while self.has_work():
            self.tick()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(f"fleet exceeded {max_ticks} ticks with "
                                   f"work remaining")
        return self.report()

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        reps = [e.report() for e in self.engines]
        routed = len(self._decisions)

        # the "fleet" block is a report section like any other subsystem's
        # (metrics.py "Section convention"); it attaches through the same
        # helper the engine's state_pool and the metrics built-ins use
        def fleet_section() -> Dict[str, Any]:
            return {
            "n_replicas": len(self.engines),
            "disaggregated": self.disaggregated,
            "ticks": self._ticks,
            "replicas": [
                {"index": i, "role": e.role,
                 "n_requests": rep["n_requests"],
                 "ttft": rep["ttft"], "tpot": rep["tpot"],
                 "e2e": rep["e2e"],
                 "throughput_tok_s": rep["throughput_tok_s"],
                 "steps": rep["engine"]["steps"],
                 "routed": self._routed_counts[i],
                 "handoffs": rep["engine"].get("handoffs")}
                for i, (e, rep) in enumerate(zip(self.engines, reps))],
            "aggregate": aggregate_fleet(reps),
            "routing": {
                "policy": self.policy,
                "affinity_weight": self.affinity_weight,
                "routed": routed,
                "per_replica": list(self._routed_counts),
                "affinity_hits": self._affinity_hits,
                "affinity_hit_rate": (self._affinity_hits / routed
                                      if routed else None),
                "affinity_hit_tokens": self._affinity_hit_tokens,
                "decisions": list(self._decisions),
            },
            "handoffs": {
                "moved": self._handoffs_moved,
                "bytes": self._handoff_bytes,
                "pending": len(self._pending),
            },
            }

        out: Dict[str, Any] = {"replica_reports": reps}
        metrics_section(out, "fleet", fleet_section)
        return out
