"""repro.serve — continuous-batching serving engine (see README.md)."""
from repro.serve.arrivals import (AdmissionQueue, VirtualClock, WallClock,
                                  load_trace, poisson_requests,
                                  trace_requests)
from repro.serve.engine import EngineConfig, ServeEngine, engine_config_for
from repro.serve.metrics import RequestRecord, ServeMetrics, percentiles
from repro.serve.paging import (NULL_BLOCK, BlockAllocator, blocks_for_tokens,
                                copy_block, gather_prefix_blocks,
                                make_paged_pool, write_chunk_blocks)
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.residency import (PREFETCH_POLICIES, ExpertResidencyManager,
                                   ResidencyCache, ResidencyDecision,
                                   TierCostModel)
from repro.serve.sampling import (nucleus_mask, sample_np, sample_tokens,
                                  truncated_probs_np)
from repro.serve.speculative import (DraftProposer, NGramProposer,
                                     greedy_verify, make_proposer,
                                     rejection_verify)

__all__ = [
    "AdmissionQueue", "BlockAllocator", "DraftProposer", "EngineConfig",
    "ExpertResidencyManager", "NGramProposer", "NULL_BLOCK",
    "PREFETCH_POLICIES",
    "Request", "RequestRecord", "RequestState", "RequestStatus",
    "ResidencyCache", "ResidencyDecision",
    "ServeEngine", "ServeMetrics", "TierCostModel", "VirtualClock",
    "WallClock",
    "blocks_for_tokens", "copy_block", "engine_config_for",
    "gather_prefix_blocks", "greedy_verify", "load_trace",
    "make_paged_pool", "make_proposer", "nucleus_mask",
    "percentiles", "poisson_requests", "rejection_verify", "sample_np",
    "sample_tokens", "trace_requests", "truncated_probs_np",
    "write_chunk_blocks",
]
