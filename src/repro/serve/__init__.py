"""repro.serve — continuous-batching serving engine (see README.md)."""
from repro.serve.arrivals import (AdmissionQueue, VirtualClock, WallClock,
                                  load_trace, poisson_requests,
                                  trace_requests)
from repro.serve.engine import EngineConfig, ServeEngine, engine_config_for
from repro.serve.metrics import RequestRecord, ServeMetrics, percentiles
from repro.serve.request import Request, RequestState, RequestStatus

__all__ = [
    "AdmissionQueue", "EngineConfig", "Request", "RequestRecord",
    "RequestState", "RequestStatus", "ServeEngine", "ServeMetrics",
    "VirtualClock", "WallClock", "engine_config_for", "load_trace",
    "percentiles", "poisson_requests", "trace_requests",
]
