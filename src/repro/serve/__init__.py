"""repro.serve — continuous-batching serving engine (see README.md)."""
from repro.serve.arrivals import (AdmissionQueue, VirtualClock, WallClock,
                                  bursty_requests, load_trace,
                                  long_context_requests, merge_requests,
                                  poisson_requests, split_seeds,
                                  trace_requests)
from repro.serve.engine import (ENGINE_ROLES, EngineConfig, ServeEngine,
                                engine_config_for)
from repro.serve.fleet import FleetRouter, ROUTING_POLICIES
from repro.serve.frontend import AdmissionFront
from repro.serve.kvstore import HandoffRecord, KVOwner
from repro.serve.metrics import (RequestRecord, ServeMetrics, aggregate_fleet,
                                 percentiles)
from repro.serve.statestore import (SequenceStateStore, SlotStateStore,
                                    make_state_store)
from repro.serve.stepcore import StepCore
from repro.serve.paging import (NULL_BLOCK, BlockAllocator, blocks_for_tokens,
                                copy_block, gather_prefix_blocks,
                                make_paged_pool, write_chunk_blocks)
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.residency import (PREFETCH_POLICIES, ExpertResidencyManager,
                                   ResidencyCache, ResidencyDecision,
                                   TierCostModel)
from repro.serve.sampling import (nucleus_mask, sample_np, sample_tokens,
                                  truncated_probs_np)
from repro.serve.speculative import (DraftProposer, NGramProposer,
                                     greedy_verify, make_proposer,
                                     rejection_verify)

__all__ = [
    "AdmissionFront", "AdmissionQueue", "BlockAllocator", "DraftProposer",
    "ENGINE_ROLES", "EngineConfig",
    "ExpertResidencyManager", "FleetRouter", "HandoffRecord", "KVOwner",
    "NGramProposer", "NULL_BLOCK",
    "PREFETCH_POLICIES",
    "ROUTING_POLICIES",
    "Request", "RequestRecord", "RequestState", "RequestStatus",
    "ResidencyCache", "ResidencyDecision",
    "SequenceStateStore", "ServeEngine", "ServeMetrics", "SlotStateStore",
    "StepCore", "TierCostModel",
    "VirtualClock", "WallClock",
    "aggregate_fleet",
    "blocks_for_tokens", "bursty_requests", "copy_block",
    "engine_config_for",
    "gather_prefix_blocks", "greedy_verify", "load_trace",
    "long_context_requests",
    "make_paged_pool", "make_proposer", "make_state_store",
    "merge_requests", "nucleus_mask",
    "percentiles", "poisson_requests", "rejection_verify", "sample_np",
    "sample_tokens", "split_seeds", "trace_requests", "truncated_probs_np",
    "write_chunk_blocks",
]
