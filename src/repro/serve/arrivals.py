"""Arrival processes and the admission queue.

Two request sources (the regimes the serving papers evaluate under):

* ``poisson_requests`` — open-loop Poisson arrivals at ``rate`` req/s with
  synthetic prompts (rate=0 degenerates to "everything arrives at t=0",
  i.e. the old one-shot batch driver).
* ``trace_requests`` — trace-driven arrivals from explicit
  (arrival_time, prompt_len, max_new_tokens) records, e.g. loaded from a
  JSON file produced by a real serving log.

The engine reads time from a ``Clock``: ``WallClock`` for real serving /
benchmarks, ``VirtualClock`` for deterministic tests (each ``now()`` call
advances a fixed dt, so arrival draining always terminates).
"""
from __future__ import annotations

import heapq
import json
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.request import Request


# ----------------------------------------------------------------------
# Clocks
# ----------------------------------------------------------------------
class WallClock:
    """Monotonic wall time, zeroed at construction.

    ``reset()`` re-zeroes the clock; the engine calls it at the start of
    each measurement window so request arrival times (which start at 0)
    are relative to the window, not to engine construction — otherwise
    TTFT would absorb jit compilation and previous runs' wall time, and
    every open-loop arrival would already be in the past.
    """

    def __init__(self):
        self._t0 = time.perf_counter()

    def reset(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait(self, dt: float) -> None:
        time.sleep(max(dt, 0.0))


class VirtualClock:
    """Deterministic clock: every ``now()`` advances by ``dt``."""

    def __init__(self, dt: float = 1.0, t0: float = 0.0):
        self.dt = dt
        self.t0 = t0
        self.t = t0

    def reset(self) -> None:
        self.t = self.t0

    def now(self) -> float:
        self.t += self.dt
        return self.t

    def wait(self, dt: float) -> None:
        self.t += max(dt, 0.0)


# ----------------------------------------------------------------------
# Request generators
# ----------------------------------------------------------------------
def poisson_requests(n: int, *, rate: float, vocab_size: int,
                     prompt_len: int, max_new_tokens: int,
                     seed: int = 0, rid_base: int = 0,
                     prompt_len_range: Optional[Tuple[int, int]] = None,
                     shared_prefix_len: int = 0,
                     eos_id: Optional[int] = None) -> List[Request]:
    """n synthetic requests with exponential inter-arrival times.

    rate <= 0 means a closed batch: all requests arrive at t=0.
    ``prompt_len_range=(lo, hi)`` draws per-request prompt lengths
    uniformly; otherwise every prompt has ``prompt_len`` tokens.
    ``shared_prefix_len=k`` makes the first ``min(k, prompt_len)`` tokens
    of every prompt identical (one draw shared across the batch) — the
    system-prompt/few-shot-template regime prefix caching targets.
    ``rid_base`` offsets the assigned rids so several sub-streams (one
    per replica / prefix group, seeded via ``split_seeds``) can be merged
    without rid collisions.
    """
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab_size,
                          (max(shared_prefix_len, 0),)).astype(np.int32)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        if prompt_len_range is not None:
            lo, hi = prompt_len_range
            plen = int(rng.integers(lo, hi + 1))
        else:
            plen = prompt_len
        toks = rng.integers(0, vocab_size, (plen,)).astype(np.int32)
        k = min(len(prefix), plen)
        if k:
            toks[:k] = prefix[:k]
        out.append(Request(rid=rid_base + i, tokens=toks,
                           max_new_tokens=max_new_tokens,
                           arrival_time=t, eos_id=eos_id))
    return out


def long_context_requests(n: int, *, vocab_size: int, max_seq_len: int,
                          max_new_tokens: int, rate: float = 0.0,
                          long_frac: float = 0.5, short_len: int = 32,
                          seed: int = 0, rid_base: int = 0,
                          eos_id: Optional[int] = None) -> List[Request]:
    """A long-context mix: ``long_frac`` of the requests carry prompts
    drawn near the pool ceiling (uniform in ``[max_seq_len // 2,
    max_seq_len - max_new_tokens]``), the rest are short (``short_len``)
    interactive prompts.  Long prompts dominate state-pool residency while
    the short ones queue behind them — the regime that exercises
    sliding-window clamping (prompts far beyond the window) and state-pool
    admission pressure.  Prompt lengths are intentionally *not* rounded to
    chunk or block multiples, so partial final chunks are always present.
    """
    if not 0.0 <= long_frac <= 1.0:
        raise ValueError("long_frac must be in [0, 1]")
    rng = np.random.default_rng(seed)
    hi = max(max_seq_len - max_new_tokens, 1)
    lo = max(min(max_seq_len // 2, hi - 1), 1)
    t = 0.0
    out: List[Request] = []
    for i in range(n):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        if rng.random() < long_frac:
            plen = int(rng.integers(lo, hi + 1))
        else:
            plen = max(min(short_len, hi), 1)
        toks = rng.integers(0, vocab_size, (plen,)).astype(np.int32)
        out.append(Request(rid=rid_base + i, tokens=toks,
                           max_new_tokens=max_new_tokens,
                           arrival_time=t, eos_id=eos_id))
    return out


def bursty_requests(n: int, *, vocab_size: int, prompt_len: int,
                    max_new_tokens: int, burst_size: int = 4,
                    burst_gap: float = 1.0, seed: int = 0,
                    rid_base: int = 0,
                    prompt_len_range: Optional[Tuple[int, int]] = None,
                    eos_id: Optional[int] = None) -> List[Request]:
    """Bursty arrivals: requests land in bursts of ``burst_size`` that
    arrive simultaneously, with ``burst_gap`` seconds of silence between
    bursts.  Each burst oversubscribes slots/blocks at one instant — the
    preemption + re-admission regime a smooth Poisson stream at the same
    mean rate rarely triggers — while the gaps let the engine drain, so
    queueing does not grow without bound over the trace."""
    if burst_size < 1:
        raise ValueError("burst_size must be >= 1")
    if burst_gap < 0:
        raise ValueError("burst_gap must be >= 0")
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    for i in range(n):
        t = (i // burst_size) * burst_gap
        if prompt_len_range is not None:
            lo, hi = prompt_len_range
            plen = int(rng.integers(lo, hi + 1))
        else:
            plen = prompt_len
        toks = rng.integers(0, vocab_size, (plen,)).astype(np.int32)
        out.append(Request(rid=rid_base + i, tokens=toks,
                           max_new_tokens=max_new_tokens,
                           arrival_time=t, eos_id=eos_id))
    return out


def split_seeds(seed: int, n: int) -> List[int]:
    """n statistically independent child seeds spawned from one root seed
    (``numpy.random.SeedSequence.spawn``) — one per replica / sub-stream,
    so a multi-replica fleet run is replayable from a single seed and no
    two sub-streams share an underlying bit stream (unlike ``seed + i``
    offsets, which can correlate)."""
    return [int(ss.generate_state(1)[0])
            for ss in np.random.SeedSequence(seed).spawn(n)]


def merge_requests(*streams: Sequence[Request]) -> List[Request]:
    """Merge per-replica/per-group sub-streams into one arrival-ordered
    trace.  Stable on arrival-time ties (earlier stream first), so the
    merged order is deterministic given deterministic sub-streams.  Rids
    are left untouched — generate sub-streams with disjoint ``rid_base``
    ranges."""
    out = [r for s in streams for r in s]
    rids = [r.rid for r in out]
    if len(set(rids)) != len(rids):
        raise ValueError("merged request streams have colliding rids; "
                         "generate sub-streams with disjoint rid_base")
    return sorted(out, key=lambda r: r.arrival_time)


def trace_requests(records: Iterable[dict], *, vocab_size: int,
                   seed: int = 0) -> List[Request]:
    """Requests from trace records: dicts with ``arrival_time``,
    ``prompt_len`` (or explicit ``tokens``), and ``max_new_tokens``."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    for i, rec in enumerate(records):
        if "tokens" in rec:
            toks = np.asarray(rec["tokens"], np.int32)
        else:
            toks = rng.integers(0, vocab_size,
                                (int(rec["prompt_len"]),)).astype(np.int32)
        out.append(Request(
            rid=int(rec.get("rid", i)), tokens=toks,
            max_new_tokens=int(rec.get("max_new_tokens", 16)),
            arrival_time=float(rec.get("arrival_time", 0.0)),
            eos_id=rec.get("eos_id")))
    return out


def load_trace(path: str, *, vocab_size: int) -> List[Request]:
    """JSON trace file: a list of record dicts (see ``trace_requests``)."""
    with open(path) as f:
        return trace_requests(json.load(f), vocab_size=vocab_size)


# ----------------------------------------------------------------------
# Admission queue
# ----------------------------------------------------------------------
class AdmissionQueue:
    """Arrival-time-ordered queue; FIFO among already-arrived requests."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._heap: List[Tuple[float, int, Request]] = []
        self._n = 0
        for r in requests:
            self.push(r)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.arrival_time, self._n, req))
        self._n += 1

    def __len__(self) -> int:
        return len(self._heap)

    def queued_tokens(self) -> int:
        """Total prompt tokens waiting in the queue (arrived or not) —
        the fleet router's measure of committed-but-unserved work."""
        return sum(r.prompt_len for _, _, r in self._heap)

    def next_arrival(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def peek_ready(self, now: float) -> Optional[Request]:
        """The earliest already-arrived request, left in the queue — the
        block-aware engine inspects it to size its KV reservation before
        committing to admission."""
        if self._heap and self._heap[0][0] <= now:
            return self._heap[0][2]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        """Pop the earliest request whose arrival time has passed."""
        if self._heap and self._heap[0][0] <= now:
            return heapq.heappop(self._heap)[2]
        return None
