"""Admission/scheduling front of the serving engine.

``AdmissionFront`` owns the request-side scheduling state: the arrival
queue, the free-slot pool, per-slot request states, the prefill pipeline
(in-flight chunked prefill plus the slot-reserved waiting line), and the
preempted-recompute queue.  It runs the admission loop — preempted
requests first, then arrivals in order, each gated by the caller's
block-reservation plan — but delegates *placement* (slot assignment, KV
chain allocation, activation) back to the engine, which knows the pool.

Splitting this state out of ``ServeEngine`` is what lets a fleet router
reason about a replica's load without touching its device state:
``queued_tokens()`` totals the prefill work parked here (queued prompts,
reserved-but-unprefilled tails, preempted recompute), the router's half
of the load score.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, List, Optional, Tuple

from repro.serve.arrivals import AdmissionQueue
from repro.serve.request import Request, RequestState


class AdmissionFront:
    def __init__(self, max_slots: int):
        self.queue = AdmissionQueue()
        self.free_slots: deque = deque(range(max_slots))
        self.state_by_slot: List[Optional[RequestState]] = [None] * max_slots
        self.slot_history: List[Tuple[int, int]] = []  # (rid, slot) admits
        self.pf: Optional[RequestState] = None       # prefill in flight
        self.pf_queue: deque = deque()               # slot reserved, waiting
        self.resume: deque = deque()                 # preempted, to recompute
        self.admit_seq = 0

    # ------------------------------------------------------------------
    def in_flight(self, active_any: bool) -> bool:
        """Admitted work whose timestamps already live on the current clock
        (queued-but-unadmitted requests carry none — their arrival_time is
        relative to the measurement window, not the clock origin).
        Preempted requests hold timestamps too."""
        return bool(self.pf is not None or self.pf_queue or self.resume
                    or active_any)

    def queued_tokens(self) -> int:
        """Prefill tokens waiting at this front: queued prompts plus the
        unconsumed tails of reserved/in-flight/preempted prefills — the
        router's measure of how much work is already committed here."""
        total = self.queue.queued_tokens()
        pending = list(self.pf_queue) + list(self.resume)
        if self.pf is not None:
            pending.append(self.pf)
        for st in pending:
            total += max(st.prefill_len - st.prefill_pos, 0)
        return total

    # ------------------------------------------------------------------
    def admit(self, now: float, *, paged: bool,
              plan_fn: Callable[[object, bool], tuple],
              can_admit_fn: Callable[[tuple], bool],
              place_fn: Callable[[RequestState, float, Optional[tuple]],
                                 None]) -> None:
        """Fill free slots: preempted recompute first (oldest first), then
        arrivals in queue order.  Paged admission is gated on the block
        plan for each candidate; the loop stops at the first candidate
        that does not fit, preserving FIFO fairness."""
        while self.free_slots:
            if self.resume:
                st = self.resume[0]
                plan = None
                if paged:
                    plan = plan_fn(st.prefill_tokens, st.resumed)
                    if not can_admit_fn(plan):
                        return
                self.resume.popleft()
                place_fn(st, now, plan)
                continue
            req = self.queue.peek_ready(now)
            if req is None:
                return
            plan = None
            if paged:
                plan = plan_fn(req.tokens, False)
                if not can_admit_fn(plan):
                    return
            self.queue.pop_ready(now)
            place_fn(RequestState(req=req, slot=-1, admitted_time=now),
                     now, plan)
