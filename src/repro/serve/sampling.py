"""Static-shape token sampling for the serve engine.

Greedy decoding is the ``temperature == 0`` degenerate case; otherwise
logits are temperature-scaled and drawn from, optionally truncated to the
``top_k`` largest via ``jax.lax.top_k`` and/or to the nucleus — the
smallest set of tokens whose cumulative probability reaches ``top_p``.
All three knobs are static at engine construction, so enabling sampling
changes *which* single entry each jit cache holds, never how many.
``top_p >= 1`` bypasses the nucleus path entirely, so draws are bit-exact
with the pre-top-p sampler there (greedy-equivalent composition).

``sample_tokens`` is the in-jit path (decode steps, batched, per-step PRNG
key); ``sample_np`` is its host-side twin used for the single first token a
finished prefill emits — the prefill logits are already on the host there,
so a numpy draw avoids touching the prefill jit signature.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def nucleus_mask(sorted_probs: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Boolean keep-mask over probabilities sorted descending along the
    last axis: True for the smallest prefix whose cumulative probability
    reaches ``top_p``.  The top token is always kept (its exclusive
    cumulative probability is 0 < top_p)."""
    cum = jnp.cumsum(sorted_probs, axis=-1)
    return (cum - sorted_probs) < top_p


def sample_tokens(logits: jnp.ndarray, key, *, temperature: float = 0.0,
                  top_k: int = 0, top_p: float = 1.0) -> jnp.ndarray:
    """logits [B, V] -> int32 [B]. Greedy when ``key`` is None or
    ``temperature <= 0``; else softmax(logits / temperature) sampling,
    truncated to the ``top_k`` largest logits when ``top_k > 0`` and to
    the ``top_p`` nucleus (within the top-k candidates) when
    ``top_p < 1``."""
    if key is None or temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    V = logits.shape[-1]
    top_k = min(top_k, V)                   # oversized k = full vocab
    if top_p < 1.0:
        # sort (full vocab, or the top-k slice — lax.top_k is descending),
        # mask everything past the nucleus, sample in sorted space, and
        # map the choice back through the sort order
        vals, idx = jax.lax.top_k(scaled, top_k if top_k > 0 else V)
        keep = nucleus_mask(jax.nn.softmax(vals, axis=-1), top_p)
        vals = jnp.where(keep, vals, -jnp.inf)
        choice = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)
    if top_k > 0:
        vals, idx = jax.lax.top_k(scaled, top_k)           # [B, k]
        choice = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def truncated_probs_np(logits_row: np.ndarray, *, temperature: float,
                       top_k: int = 0, top_p: float = 1.0
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """The truncated categorical ``sample_np`` draws from, materialized:
    ``(candidate token ids, their probabilities)`` for one row of logits
    at ``temperature > 0``.  Shared with the speculative rejection sampler
    (``serve.speculative``), which must accept/resample against exactly
    this distribution to stay distribution-identical with the base
    sampler."""
    x = np.asarray(logits_row, np.float64) / temperature
    top_k = min(top_k, x.shape[0])          # oversized k = full vocab
    # tie-breaking must mirror jax.lax.top_k, which keeps the LOWEST
    # indices among equal values: np.argpartition selects an arbitrary
    # subset of a tie straddling the k-th place (and unstable argsort an
    # arbitrary order inside the nucleus), so the host twin could keep a
    # different candidate set than the device sampler on tie-heavy logits
    # (differential-tested in tests/test_sampling_twins.py)
    if top_k > 0:
        keep = np.argsort(-x, kind="stable")[:top_k]
        x = x[keep]
    else:
        keep = np.arange(x.shape[0])
    if top_p < 1.0:
        order = np.argsort(-x, kind="stable")
        keep, x = keep[order], x[order]
        p = np.exp(x - x.max())
        p /= p.sum()
        inside = (np.cumsum(p) - p) < top_p
        keep, x = keep[inside], x[inside]
    p = np.exp(x - x.max())
    p /= p.sum()
    return keep, p


def sample_np(logits_row: np.ndarray, rng: Optional[np.random.Generator], *,
              temperature: float = 0.0, top_k: int = 0,
              top_p: float = 1.0) -> int:
    """Host-side twin of ``sample_tokens`` for one row of logits."""
    logits_row = np.asarray(logits_row, np.float64)
    if rng is None or temperature <= 0:
        return int(np.argmax(logits_row))
    keep, p = truncated_probs_np(logits_row, temperature=temperature,
                                 top_k=top_k, top_p=top_p)
    return int(keep[rng.choice(p.shape[0], p=p)])
