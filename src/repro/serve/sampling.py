"""Static-shape token sampling for the serve engine.

Greedy decoding is the ``temperature == 0`` degenerate case; otherwise
logits are temperature-scaled and drawn from, optionally truncated to the
``top_k`` largest via ``jax.lax.top_k``.  Both knobs are static at engine
construction, so enabling sampling changes *which* single entry each jit
cache holds, never how many.

``sample_tokens`` is the in-jit path (decode steps, batched, per-step PRNG
key); ``sample_np`` is its host-side twin used for the single first token a
finished prefill emits — the prefill logits are already on the host there,
so a numpy draw avoids touching the prefill jit signature.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def sample_tokens(logits: jnp.ndarray, key, *, temperature: float = 0.0,
                  top_k: int = 0) -> jnp.ndarray:
    """logits [B, V] -> int32 [B]. Greedy when ``key`` is None or
    ``temperature <= 0``; else softmax(logits / temperature) sampling,
    truncated to the ``top_k`` largest logits when ``top_k > 0``."""
    if key is None or temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    top_k = min(top_k, logits.shape[-1])    # oversized k = full vocab
    if top_k > 0:
        vals, idx = jax.lax.top_k(scaled, top_k)           # [B, k]
        choice = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample_np(logits_row: np.ndarray, rng: Optional[np.random.Generator], *,
              temperature: float = 0.0, top_k: int = 0) -> int:
    """Host-side twin of ``sample_tokens`` for one row of logits."""
    logits_row = np.asarray(logits_row, np.float64)
    if rng is None or temperature <= 0:
        return int(np.argmax(logits_row))
    x = logits_row / temperature
    top_k = min(top_k, x.shape[0])          # oversized k = full vocab
    if top_k > 0:
        keep = np.argpartition(x, -top_k)[-top_k:]
        x = x[keep]
    else:
        keep = np.arange(x.shape[0])
    p = np.exp(x - x.max())
    p /= p.sum()
    return int(keep[rng.choice(p.shape[0], p=p)])
