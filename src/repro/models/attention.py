"""Attention: GQA/MHA with RoPE, sliding window, logit softcap.

Prefill/train path: chunked ("flash-style") attention — a lax.scan over KV
blocks with an online softmax, so the [S, S] score matrix is never
materialized (memory-roofline honest at 32k). The Pallas flash kernel
(kernels/flash_attention) is the TPU-target equivalent; this is its oracle
twin used for dry-runs and CPU tests.

Decode path: one query position against a static-size KV cache with position
masking. Distributed long-context decode works by *sharding constraint*: the
cache's sequence dim carries P('data') and XLA partitions the reduction
(distributed softmax) — no shard_map needed (DESIGN.md §3 SP).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope

_NEG_INF = -1e30


class FusedPathUnavailable(NotImplementedError):
    """``use_pallas`` was required (strict) but no fused kernel applies."""


# Trace-time dispatch record: ``attention_block`` runs under jit, so each
# record is appended exactly once per traced call site (one trace covers
# every execution of that entry) — the log is therefore a faithful
# kernel-coverage map of which branches dispatched the fused Pallas path
# vs the reference gather, and *why* a requested fused path fell back.
# The serve engine resets it before warmup and snapshots it after.
_dispatch_log: list = []
_DISPATCH_LOG_CAP = 4096


def reset_dispatch_log() -> None:
    _dispatch_log.clear()


def dispatch_log() -> list:
    return list(_dispatch_log)


def fallback_counts(log: Optional[list] = None) -> Dict[str, int]:
    """Branches where ``use_pallas`` was requested but the reference path
    ran anyway (the previously *silent* fallbacks), keyed by branch.

    Counts over the live module log by default; pass a snapshot from
    ``dispatch_log()`` to count over a captured window instead."""
    out: Dict[str, int] = {}
    for rec in (_dispatch_log if log is None else log):
        if rec["requested"] and not rec["fused"]:
            out[rec["branch"]] = out.get(rec["branch"], 0) + 1
    return out


def _record_dispatch(branch: str, *, fused: bool, requested: bool,
                     strict: bool = False, reason: str = "") -> None:
    if len(_dispatch_log) < _DISPATCH_LOG_CAP:
        _dispatch_log.append({"branch": branch, "fused": bool(fused),
                              "requested": bool(requested),
                              "reason": reason})
    if requested and not fused and strict:
        raise FusedPathUnavailable(
            f"attention_block: use_pallas was explicitly required but the "
            f"fused path cannot apply on branch {branch!r}: {reason}")


def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> Dict[str, jnp.ndarray]:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = (2.0 / d) ** 0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * s).astype(dtype),
    }


def _softcap(scores: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return scores
    return cap * jnp.tanh(scores / cap)


def _repeat_kv(k: jnp.ndarray, rep: int) -> jnp.ndarray:
    """GQA: repeat kv heads to the q-head count. An explicit repeat keeps the
    q-head sharding intact (a [Hkv, rep] reshape would split across the
    sharded head dim and force XLA to all-gather q — observed 8.6 GB/chunk
    score blowups on mixtral prefill before this change)."""
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: int = 0, softcap: float = 0.0,
                      chunk: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention.

    q: [B, Sq, H, hd]; k/v: [B, Sk, Hkv, hd] with H = Hkv * rep.
    Scans KV in blocks of ``chunk``; running (max, denom, acc) carried.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    Skp = n_chunks * chunk
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    k = _repeat_kv(k, rep)
    v = _repeat_kv(v, rep)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale                     # [B, Sq, H, hd]
    q_pos = q_offset + jnp.arange(Sq)

    kc = k.reshape(B, n_chunks, chunk, H, hd)
    vc = v.reshape(B, n_chunks, chunk, H, hd)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, c0 = inp
        kv_pos = c0 + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        s = _softcap(s, softcap)
        mask = kv_pos[None, :] < Sk
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, None, :, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    c0s = jnp.arange(n_chunks) * chunk
    # checkpoint the chunk body: backward recomputes the [Sq, chunk] score
    # block instead of storing it per chunk (flash-style backward memory)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), c0s))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def full_attention_ref(q, k, v, *, causal, window=0, softcap=0.0, q_offset=0):
    """Naive O(S^2)-memory oracle for tests."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    k = _repeat_kv(k, rep)
    v = _repeat_kv(v, rep)
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    s = _softcap(s, softcap)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray, *, window: int = 0,
                     softcap: float = 0.0) -> jnp.ndarray:
    """q: [B, 1, H, hd]; caches: [B, S_max, Hkv, hd]; cache_len: scalar int or
    per-sequence [B] vector (entries < cache_len are valid; the new token's
    K/V must already be written at cache_len - 1). The vector form is what
    lets a continuous-batching slot pool hold sequences of different lengths
    in one static-shape decode step."""
    B, _, H, hd = q.shape
    S_max, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    kr = _repeat_kv(k_cache, rep)
    vr = _repeat_kv(v_cache, rep)
    qf = (q.astype(jnp.float32) * hd ** -0.5)[:, 0]        # [B, H, hd]
    s = jnp.einsum("bhd,bkhd->bhk", qf, kr.astype(jnp.float32))
    s = _softcap(s, softcap)
    kv_pos = jnp.arange(S_max)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        cl = cl[None]                                      # broadcast over B
    mask = kv_pos[None, :] < cl[:, None]                   # [B|1, S_max]
    if window > 0:
        mask = mask & (kv_pos[None, :] >= cl[:, None] - window)
    s = jnp.where(mask[:, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           cache_len: jnp.ndarray, *, block_size: int,
                           softcap: float = 0.0) -> jnp.ndarray:
    """Decode attention through a paged KV pool.

    q: [B, S, H, hd] with S >= 1 query positions (S = 1 is plain decode;
    S = k + 1 is a speculative-verify window); k_pool/v_pool:
    [1, P, Hkv, hd] *physical* pools with P = num_blocks * block_size;
    block_table: [B, max_blocks_per_slot] int32 mapping each row's logical
    block j to a physical block id; cache_len: per-row [B] valid lengths
    INCLUDING the S window positions (query i sits at absolute position
    ``cache_len - S + i``).  Each row's logical K/V view is gathered
    through its table row (unallocated entries point at the null block,
    whose garbage the validity mask hides), then reduced by the same
    masked-softmax decode attention the slab pool uses — causal within
    the window when S > 1.
    """
    n_logical = block_table.shape[1]
    log = jnp.arange(n_logical * block_size)
    phys = block_table[:, log // block_size] * block_size \
        + log % block_size                                  # [B, L_max]
    k = k_pool[0, phys]                                     # [B, L_max, Hkv, hd]
    v = v_pool[0, phys]
    S = q.shape[1]
    if S == 1:
        return decode_attention(q, k, v, cache_len, softcap=softcap)
    # multi-query verify window: per-query causal mask inside the window
    B, _, H, hd = q.shape
    rep = H // k.shape[2]
    kr = _repeat_kv(k, rep)
    vr = _repeat_kv(v, rep)
    qf = q.astype(jnp.float32) * hd ** -0.5                 # [B, S, H, hd]
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr.astype(jnp.float32))
    s = _softcap(s, softcap)
    cl = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,))
    q_pos = cl[:, None] - S + jnp.arange(S)[None]           # [B, S]
    mask = log[None, None, :] <= q_pos[:, :, None]          # [B, S, L_max]
    s = jnp.where(mask[:, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bhqd", p, vr.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def paged_ring_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                                v_pool: jnp.ndarray,
                                block_table: jnp.ndarray,
                                cache_len: jnp.ndarray, *, window: int,
                                block_size: int,
                                softcap: float = 0.0) -> jnp.ndarray:
    """Single-query decode through a paged pool whose logical positions wrap
    a ring of M = round_up(window, block_size) positions.

    Absolute position p lives at ring slot p % M (block ``(p % M) //
    block_size`` of the row's chain), so a chain of M/block_size blocks
    serves an unbounded logical length: the decode write at
    ``(cache_len - 1) % M`` overwrites the age-M position, which the window
    (window <= M) has already expired.  Ring slot r holds absolute position
    ``cache_len - 1 - ((cache_len - 1 - r) mod M)`` — valid iff that age is
    < min(window, cache_len).  K is stored post-RoPE at its absolute
    position, exactly as in the slab ring, so scores stay position-exact
    across wraps.
    """
    B = q.shape[0]
    M = -(-window // block_size) * block_size
    r = jnp.arange(M)
    phys = block_table[:, r // block_size] * block_size \
        + r % block_size                                    # [B, M]
    k = k_pool[0, phys]                                     # [B, M, Hkv, hd]
    v = v_pool[0, phys]
    cl = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    age = jnp.mod(cl[:, None] - 1 - r[None, :], M)          # [B, M]
    valid = (age < window) & (age < cl[:, None])
    H, hd = q.shape[2], q.shape[3]
    rep = H // k.shape[2]
    kr = _repeat_kv(k, rep)
    vr = _repeat_kv(v, rep)
    qf = (q.astype(jnp.float32) * hd ** -0.5)[:, 0]         # [B, H, hd]
    s = jnp.einsum("bhd,bkhd->bhk", qf, kr.astype(jnp.float32))
    s = _softcap(s, softcap)
    s = jnp.where(valid[:, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


class AttnCache(NamedTuple):
    k: jnp.ndarray   # [B, S_max, Hkv, hd]
    v: jnp.ndarray


def attention_block(x: jnp.ndarray, p: Dict[str, jnp.ndarray],
                    cfg: ModelConfig, *, causal: bool = True,
                    is_global: bool = True, q_offset: int = 0,
                    cache: Optional[AttnCache] = None,
                    cache_len: Optional[jnp.ndarray] = None,
                    kv_source: Optional[jnp.ndarray] = None,
                    attn_chunk: int = 1024,
                    use_pallas: bool = False, interpret: bool = False,
                    continue_prefill: bool = False,
                    block_table: Optional[jnp.ndarray] = None,
                    block_size: int = 0,
                    strict_pallas: bool = False,
                    ) -> Tuple[jnp.ndarray, Optional[AttnCache]]:
    """Full attention sub-layer (projections + RoPE + attention + out-proj).

    Modes:
      * prefill/train: cache=None -> chunked attention over x itself
        (or ``kv_source`` for cross-attention), returns fresh cache if
        cache_len is not None.
      * decode: cache given, x is [B, 1, d]; writes K/V at cache_len-1.
        ``q_offset``/``cache_len`` may be per-sequence [B] vectors (slotted
        continuous batching), in which case K/V lands at each row's own slot.
      * paged decode (``block_table`` given): cache is a batch-1 *physical*
        block pool; each row's K/V is written at its block-translated
        position and attention reads through the table — either the
        reference gather (``paged_decode_attention``) or, with
        ``use_pallas``, the fused Pallas kernel
        (``kernels.paged_attention``) that walks the block table inside
        the kernel and never materializes the logical view.  Requires
        window-free attention over the logical range (validated here: a
        binding sliding window raises).
      * chunked-prefill continuation (``continue_prefill``): cache given and
        x is a [B, C] prompt chunk starting at position ``q_offset`` (scalar);
        writes K/V at [q_offset, q_offset + C).  With ``use_pallas`` the
        slab cache is viewed as a pool of contiguous per-row blocks with
        an identity block table and ``cache_len = q_offset + C``, so the
        SAME q-tiled paged kernel serves chunked prefill and prefix-tail
        prefill (the kernel's causal pruning skips kv tiles past
        ``q_offset + C`` — the reference ``chunked_attention`` scans the
        whole [B, S_max] slab every chunk).  Otherwise the chunked
        reference attends over the full cache, the causal mask hiding
        the unwritten tail.

    Every branch records its dispatch decision (fused kernel vs reference)
    into the module-level trace-time log — see ``dispatch_log`` /
    ``fallback_counts``.  ``strict_pallas=True`` turns a requested-but-
    inapplicable fused path from a silent fallback into a loud
    ``FusedPathUnavailable`` at trace time.
    """
    B, S, d = x.shape
    window = 0 if (is_global and cfg.global_attn_every) else cfg.sliding_window
    softcap = cfg.attn_logit_softcap
    kv_in = x if kv_source is None else kv_source

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"])

    if cfg.rope_theta > 0 and kv_source is None:
        qo = jnp.asarray(q_offset)
        off = qo[..., None] if qo.ndim else qo     # [B, 1] or scalar
        q = apply_rope(q, off + jnp.arange(S), cfg.rope_theta)
        k = apply_rope(k, off + jnp.arange(kv_in.shape[1]), cfg.rope_theta)

    new_cache = None
    if cache is not None and S > 1 and continue_prefill:
        S_max = cache.k.shape[1]
        start = jnp.asarray(q_offset, jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, start, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, start, 0, 0))
        # fused path: view the [B, S_max] slab as B contiguous block
        # chains and run the q-tiled paged kernel with an identity table
        # and cache_len = q_offset + S — the kernel's mask (query i at
        # absolute position cache_len - S + i = q_offset + i) matches
        # chunked_attention(..., q_offset=q_offset) exactly, and its
        # causal pruning stops at q_offset + S instead of scanning the
        # whole slab.  A window can only be ignored when it cannot bind
        # over the slab (window >= S_max).
        fuse = use_pallas and causal and (window == 0 or window >= S_max)
        if fuse:
            from repro.kernels.paged_attention.ops import (
                largest_block_divisor, paged_attention)
            bs_slab = largest_block_divisor(S_max)
            nb = S_max // bs_slab
            Hkv, hd = k_cache.shape[2], k_cache.shape[3]
            table = (jnp.arange(B, dtype=jnp.int32)[:, None] * nb
                     + jnp.arange(nb, dtype=jnp.int32)[None, :])
            cl = jnp.broadcast_to(start + S, (B,))
            _record_dispatch("prefill_continue", fused=True,
                             requested=use_pallas)
            out = paged_attention(
                q, k_cache.reshape(1, B * S_max, Hkv, hd),
                v_cache.reshape(1, B * S_max, Hkv, hd), table, cl,
                block_size=bs_slab, softcap=softcap, interpret=interpret)
        else:
            _record_dispatch(
                "prefill_continue", fused=False, requested=use_pallas,
                strict=strict_pallas,
                reason=("non-causal attention" if not causal else
                        f"binding sliding window {window} < slab {S_max}"))
            out = chunked_attention(q, k_cache, v_cache, causal=causal,
                                    window=window, softcap=softcap,
                                    chunk=attn_chunk, q_offset=q_offset)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, AttnCache(k_cache, v_cache)
    if cache is not None and S > 1 and block_table is None:
        # prefill with a pre-allocated cache: full causal attention over x,
        # then write the computed K/V into the cache prefix [0, S).
        if use_pallas and causal and window == 0 and softcap == 0.0:
            from repro.kernels.flash_attention.ops import flash_attention
            _record_dispatch("prefill_cache", fused=True, requested=True)
            out = flash_attention(q, k, v, causal=True, interpret=interpret)
        else:
            _record_dispatch(
                "prefill_cache", fused=False, requested=use_pallas,
                strict=strict_pallas,
                reason=(f"flash kernel guards failed (causal={causal}, "
                        f"window={window}, softcap={softcap})"))
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap, chunk=attn_chunk,
                                    q_offset=0)
        S_max = cache.k.shape[1]
        kw = k[:, :S_max].astype(cache.k.dtype)
        vw = v[:, :S_max].astype(cache.v.dtype)
        if S >= S_max and window > 0 and S_max <= window:
            # ring: keep the window tail, each position p at its ring slot
            # p % S_max — decode writes land at (cache_len - 1) % S_max, so
            # storing the tail flat at [0, S_max) would leave the ring
            # rotated by S % S_max and decode would evict a mid-window
            # token instead of the oldest whenever S % S_max != 0
            kw = jnp.roll(k[:, S - S_max:], S % S_max,
                          axis=1).astype(cache.k.dtype)
            vw = jnp.roll(v[:, S - S_max:], S % S_max,
                          axis=1).astype(cache.v.dtype)
        k_cache = jax.lax.dynamic_update_slice(cache.k, kw, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, vw, (0, 0, 0, 0))
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, AttnCache(k_cache, v_cache)
    if cache is not None and block_table is not None:
        # paged decode / speculative verify: translate each row's S write
        # positions through its block-table row, scatter into the physical
        # pool, gather-attend (causal within the window when S > 1).
        # Inactive rows (cache_len=S, all-null table) write into the null
        # block — garbage that the validity mask keeps unread.
        L_max = block_table.shape[1] * block_size
        # ring mode: a binding sliding window wraps the logical position
        # into a ring of M = round_up(window, block_size) positions, so a
        # chain of M/block_size blocks serves unbounded logical lengths.
        # window > L_max cannot bind (the engine caps logical positions at
        # L_max there) and keeps the window-free path; window == L_max is
        # equivalent under either arithmetic (cl <= M => pos % M == pos).
        ring = 0 < window <= L_max
        if ring and S > 1:
            raise NotImplementedError(
                f"paged sliding-window ring decode (window={window}) is "
                f"single-query only; speculative verify windows are "
                f"rejected for windowed models at EngineConfig validation")
        cl = jnp.broadcast_to(
            jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
        pos = cl[:, None] - S + jnp.arange(S)[None]         # [B, S]
        if ring:
            M = -(-window // block_size) * block_size
            pos = pos % M
        widx = block_table[jnp.arange(B)[:, None], pos // block_size] \
            * block_size + pos % block_size                 # [B, S]
        k_cache = cache.k.at[0, widx].set(k.astype(cache.k.dtype))
        v_cache = cache.v.at[0, widx].set(v.astype(cache.v.dtype))
        branch = "verify" if S > 1 else "decode"
        if ring:
            _record_dispatch(
                "decode_ring", fused=False, requested=use_pallas,
                strict=strict_pallas,
                reason=f"sliding-window ring decode (window={window}) has "
                       f"no fused kernel")
            out = paged_ring_decode_attention(
                q, k_cache, v_cache, block_table, cl, window=window,
                block_size=block_size, softcap=softcap)
        elif use_pallas:
            from repro.kernels.paged_attention.ops import paged_attention
            _record_dispatch(branch, fused=True, requested=True)
            out = paged_attention(q, k_cache, v_cache, block_table, cl,
                                  block_size=block_size, softcap=softcap,
                                  interpret=interpret)
        else:
            _record_dispatch(branch, fused=False, requested=False,
                             reason="use_pallas not requested")
            out = paged_decode_attention(q, k_cache, v_cache, block_table,
                                         cl, block_size=block_size,
                                         softcap=softcap)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, AttnCache(k_cache, v_cache)
    if cache is not None:
        # decode: write the new K/V at position cache_len-1, attend over cache.
        # Sliding-window caches sized to the window act as ring buffers
        # (mixtral long_500k): slots are overwritten in place and the window
        # constraint is enforced by the overwrite itself.
        S_max = cache.k.shape[1]
        ring = window > 0 and S_max <= window
        cl = jnp.asarray(cache_len)
        pos = ((cl - 1) % S_max) if ring else (cl - 1)
        if cl.ndim:
            # per-slot positions: scatter each row's K/V at its own index
            bidx = jnp.arange(B)
            k_cache = cache.k.at[bidx, pos].set(k[:, 0].astype(cache.k.dtype))
            v_cache = cache.v.at[bidx, pos].set(v[:, 0].astype(cache.v.dtype))
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, pos, 0, 0))
        eff_len = jnp.minimum(cl, S_max) if ring else cl
        _record_dispatch(
            "decode_slab", fused=False, requested=use_pallas,
            strict=strict_pallas,
            reason="slab decode has no fused kernel (paged pool required)")
        out = decode_attention(q, k_cache, v_cache, eff_len,
                               window=0 if ring else window, softcap=softcap)
        new_cache = AttnCache(k_cache, v_cache)
    else:
        if use_pallas and causal and window == 0 and softcap == 0.0:
            from repro.kernels.flash_attention.ops import flash_attention
            _record_dispatch("prefill", fused=True, requested=True)
            out = flash_attention(q, k, v, causal=True, interpret=interpret)
        else:
            _record_dispatch(
                "prefill", fused=False, requested=use_pallas,
                strict=strict_pallas,
                reason=(f"flash kernel guards failed (causal={causal}, "
                        f"window={window}, softcap={softcap})"))
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap, chunk=attn_chunk,
                                    q_offset=q_offset)
        if cache_len is not None:
            # prefill: keep the K/V we just computed as the cache prefix
            new_cache = AttnCache(k, v)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache
