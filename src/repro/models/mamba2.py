"""Mamba2 block: SSD (state-space duality, arXiv:2405.21060).

Prefill/train: the chunked SSD algorithm — a lax.scan over sequence chunks;
within a chunk the quadratic (attention-like) form is used, across chunks the
recurrent state [B, H, P, N] is carried. Linear in sequence length, so
long_500k decodes/prefills without quadratic blowup.

Decode: the O(1) recurrence h <- dA*h + dt*x (x) B, y = h . C.

Projections are stored per-component (z, x, B, C, dt) rather than as one
fused in_proj so each can carry its natural TP sharding (x/z: column-parallel
over 'model'; B/C/dt tiny, replicated) without mid-tensor resharding; the
causal convs are likewise per-component. B and C are shared across heads
(ngroups=1) as in the reference implementation.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import rmsnorm


class MambaDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    state: int
    conv_w: int


def mamba_dims(cfg: ModelConfig) -> MambaDims:
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.num_heads or (d_inner // s.head_dim)
    return MambaDims(d_inner, n_heads, s.head_dim, s.state_dim, s.conv_width)


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype) -> Dict[str, jnp.ndarray]:
    dm = mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    s_in = (2.0 / d) ** 0.5
    return {
        "wz": (jax.random.normal(ks[0], (d, dm.d_inner)) * s_in).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, dm.d_inner)) * s_in).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, dm.state)) * s_in).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, dm.state)) * s_in).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, dm.n_heads)) * s_in).astype(dtype),
        "out_proj": (jax.random.normal(ks[5], (dm.d_inner, d))
                     * (2.0 / dm.d_inner) ** 0.5).astype(dtype),
        "conv_x": (jax.random.normal(ks[6], (dm.conv_w, dm.d_inner)) * 0.2
                   ).astype(dtype),
        "conv_B": (jax.random.normal(ks[7], (dm.conv_w, dm.state)) * 0.2
                   ).astype(dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(key, 9),
                                     (dm.conv_w, dm.state)) * 0.2).astype(dtype),
        "conv_bx": jnp.zeros((dm.d_inner,), dtype),
        "conv_bB": jnp.zeros((dm.state,), dtype),
        "conv_bC": jnp.zeros((dm.state,), dtype),
        "A_log": jnp.log(jnp.arange(1, dm.n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((dm.n_heads,), jnp.float32),
        "dt_bias": jnp.full((dm.n_heads,), -2.0, jnp.float32),
        "norm_scale": jnp.zeros((dm.d_inner,), jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None,
                 valid_len: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv + silu. x: [B, L, C]; w: [W, C]; state: last W-1
    inputs (for decode continuity). ``valid_len`` [B] or scalar: only the
    first ``valid_len`` positions of ``x`` are real tokens — the carried
    ``new_state`` then holds the last W-1 *valid* inputs, so a padded final
    prefill chunk does not fold pad activations into the state."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    if W > 1:
        if valid_len is None:
            new_state = xp[:, -(W - 1):, :]
        else:
            # valid x tokens occupy xp[:, W-1 : W-1+vl]; the last W-1 valid
            # inputs (state included, for vl < W-1) are xp[:, vl : vl+W-1].
            vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32),
                                  (x.shape[0],))
            idx = vl[:, None] + jnp.arange(W - 1)[None, :]        # [B, W-1]
            new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    else:
        new_state = state
    return jax.nn.silu(y + b[None, None, :]), new_state


class MambaState(NamedTuple):
    ssm: jnp.ndarray      # [B, H, P, N] float32
    conv_x: jnp.ndarray   # [B, W-1, d_inner]
    conv_B: jnp.ndarray   # [B, W-1, N]
    conv_C: jnp.ndarray   # [B, W-1, N]


def init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> MambaState:
    dm = mamba_dims(cfg)
    return MambaState(
        ssm=jnp.zeros((batch, dm.n_heads, dm.head_dim, dm.state), jnp.float32),
        conv_x=jnp.zeros((batch, dm.conv_w - 1, dm.d_inner), dtype),
        conv_B=jnp.zeros((batch, dm.conv_w - 1, dm.state), dtype),
        conv_C=jnp.zeros((batch, dm.conv_w - 1, dm.state), dtype))


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bs: jnp.ndarray, Cs: jnp.ndarray, *, chunk: int,
                init_ssm: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    xh: [B, L, H, P]; dt: [B, L, H] (post-softplus); A: [H] (negative);
    Bs/Cs: [B, L, N]. Returns (y [B, L, H, P], final state [B, H, P, N]).
    """
    Bb, L, H, Pd = xh.shape
    N = Bs.shape[-1]
    Q = min(chunk, L)
    n_chunks = -(-L // Q)
    Lp = n_chunks * Q
    if Lp != L:
        xh = jnp.pad(xh, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Lp - L), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, Lp - L), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, Lp - L), (0, 0)))

    xh = xh.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bs = Bs.astype(jnp.float32)
    Cs = Cs.astype(jnp.float32)

    logdA = dt * A[None, None, :]                                # [B, Lp, H]

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bb, n_chunks, Q, *t.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc, ldc = map(to_chunks, (xh, dt, Bs, Cs, logdA))

    def step(state, inp):
        x_q, dt_q, B_q, C_q, ld_q = inp                          # [B, Q, ...]
        Lcum = jnp.cumsum(ld_q, axis=1)                          # [B, Q, H]
        # within-chunk quadratic form
        G = jnp.einsum("bqn,bsn->bqs", C_q, B_q)                 # [B, Q, Q]
        decay = jnp.exp(Lcum[:, :, None, :] - Lcum[:, None, :, :])  # [B,Q,S,H]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        W = jnp.where(tri[None, :, :, None], G[..., None] * decay, 0.0)
        xdt = x_q * dt_q[..., None]                              # [B, Q, H, P]
        y_diag = jnp.einsum("bqsh,bshp->bqhp", W, xdt)
        # off-diagonal: contribution of the carried state
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", C_q, state,
                           jnp.exp(Lcum))
        # chunk state and carry update
        rem = jnp.exp(Lcum[:, -1:, :] - Lcum)                    # decay to chunk end
        S_c = jnp.einsum("bsh,bshp,bsn->bhpn", rem, xdt, B_q)
        state_new = state * jnp.exp(Lcum[:, -1])[:, :, None, None] + S_c
        return state_new, y_diag + y_off

    state0 = (jnp.zeros((Bb, H, Pd, N), jnp.float32)
              if init_ssm is None else init_ssm)
    state_f, ys = jax.lax.scan(jax.checkpoint(step), state0,
                               (xc, dtc, Bc, Cc, ldc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, Lp, H, Pd)[:, :L]
    return y, state_f


def ssd_recurrent_ref(xh, dt, A, Bs, Cs):
    """Naive per-step recurrence oracle for tests."""
    Bb, L, H, Pd = xh.shape
    N = Bs.shape[-1]
    state = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    ys = []
    for t in range(L):
        dA = jnp.exp(dt[:, t] * A[None, :])                      # [B, H]
        upd = jnp.einsum("bhp,bn->bhpn", xh[:, t] * dt[:, t, :, None], Bs[:, t])
        state = state * dA[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, Cs[:, t]))
    return jnp.stack(ys, axis=1), state


def mamba_block(x: jnp.ndarray, p: Dict[str, jnp.ndarray], cfg: ModelConfig,
                *, state: Optional[MambaState] = None,
                valid_len: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Optional[MambaState]]:
    """x: [B, L, d] -> [B, L, d]. state given => stateful (decode or resume).

    ``valid_len`` ([B] or scalar) marks the first ``valid_len`` positions as
    real tokens: pad positions get dt = 0 (an exact identity SSD update —
    dA = exp(0) = 1 with a zero input term) and the conv states slice at the
    last valid input, so a padded final prefill chunk leaves ``new_state``
    token-exact. Outputs at pad positions are garbage either way.
    """
    dm = mamba_dims(cfg)
    dtype = x.dtype
    z = x @ p["wz"]
    xc = x @ p["wx"]
    Bs = x @ p["wB"]
    Cs = x @ p["wC"]
    dt = x @ p["wdt"]
    cx = state.conv_x if state is not None else None
    cB = state.conv_B if state is not None else None
    cC = state.conv_C if state is not None else None
    xc, ncx = _causal_conv(xc, p["conv_x"], p["conv_bx"], cx, valid_len)
    Bs, ncB = _causal_conv(Bs, p["conv_B"], p["conv_bB"], cB, valid_len)
    Cs, ncC = _causal_conv(Cs, p["conv_C"], p["conv_bC"], cC, valid_len)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (x.shape[0],))
        dt = dt * (jnp.arange(x.shape[1])[None, :] < vl[:, None])[..., None]
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(*xc.shape[:2], dm.n_heads, dm.head_dim)

    L = x.shape[1]
    init_ssm = state.ssm if state is not None else None
    if L == 1 and state is not None:
        # decode: single recurrence step
        dA = jnp.exp(dt[:, 0] * A[None, :])                      # [B, H]
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]
        upd = jnp.einsum("bhp,bn->bhpn", xdt, Bs[:, 0].astype(jnp.float32))
        ssm = init_ssm * dA[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", ssm, Cs[:, 0].astype(jnp.float32))
        y = y[:, None]                                           # [B, 1, H, P]
    else:
        y, ssm = ssd_chunked(xh, dt, A, Bs.astype(jnp.float32),
                             Cs.astype(jnp.float32), chunk=cfg.ssm.chunk_size,
                             init_ssm=init_ssm)
    new_state = (MambaState(ssm, ncx, ncB, ncC)
                 if state is not None else None)

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(*y.shape[:2], dm.d_inner).astype(dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["out_proj"], new_state
