"""Shared building blocks: norms, RoPE, MLPs, embeddings."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Reduction in f32, scaling in the input dtype: the normalized output
    stays bf16, so downstream SP all-gathers move bf16 not f32 (halved
    collective bytes — EXPERIMENTS.md §Perf iteration 3)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = (jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)
    return x * inv


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return ((x - mu.astype(x.dtype)) * (inv * scale).astype(x.dtype)
            + bias.astype(x.dtype))


def norm(x: jnp.ndarray, p: Dict[str, jnp.ndarray], kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def init_norm(d: int, kind: str) -> Dict[str, jnp.ndarray]:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..,S,1,hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal embeddings [S, d]."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


# ----------------------------------------------------------------------
# Dense MLPs
# ----------------------------------------------------------------------
def init_mlp(key: jax.Array, d: int, f: int, act: str, dtype) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 3)
    si, so = (2.0 / d) ** 0.5, (2.0 / f) ** 0.5
    p = {"w_in": (jax.random.normal(ks[0], (d, f)) * si).astype(dtype),
         "w_out": (jax.random.normal(ks[1], (f, d)) * so).astype(dtype)}
    if act in ("swiglu", "gelu"):  # gated variants (geglu for gemma2)
        p["w_gate"] = (jax.random.normal(ks[2], (d, f)) * si).astype(dtype)
    return p


def mlp(x: jnp.ndarray, p: Dict[str, jnp.ndarray], act: str) -> jnp.ndarray:
    h = x @ p["w_in"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_gate"]) * h
    elif act == "gelu_mlp":
        h = jax.nn.gelu(h)
    elif act == "relu_mlp":
        h = jax.nn.relu(h)
    else:
        raise ValueError(act)
    return h @ p["w_out"]


def init_embedding(key: jax.Array, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)
