"""Layer stacks: dense/MoE decoder (scan over layer-pattern periods), the
zamba2 hybrid stack, and the whisper encoder-decoder.

All stacks scan over layers with stacked parameters so the HLO stays compact
(one layer body per pattern position) — essential for compiling 40+ cells of
the dry-run matrix quickly and the standard structure for PP-free deep
models. ``jax.checkpoint`` wraps the scan body when remat is requested.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.moe_layer import MoEBlockSpec, init_moe_params, moe_block
from repro.models import attention as A
from repro.models import mamba2 as M
from repro.models.layers import init_mlp, init_norm, mlp, norm


# ----------------------------------------------------------------------
# Layer pattern description
# ----------------------------------------------------------------------
def layer_pattern(cfg: ModelConfig) -> Tuple[List[str], int, int]:
    """Return (pattern, n_steps, n_lead_dense).

    pattern: layer kinds within one scan period, e.g. ["dense"],
    ["attn_local", "attn_global"], ["dense", "moe"]. The stack scans
    n_steps periods; ``n_lead_dense`` leading dense layers are unscanned
    (moonshot's first dense layer).
    """
    lead = cfg.moe.first_dense_layers if cfg.is_moe else 0
    L = cfg.num_layers - lead
    if cfg.family == "ssm":
        return ["mamba"], cfg.num_layers, 0
    if cfg.is_moe and cfg.moe.moe_layer_period > 1:
        p = cfg.moe.moe_layer_period
        assert L % p == 0
        pat = ["dense"] * p
        pat[cfg.moe.moe_layer_offset] = "moe"
        return pat, L // p, lead
    if cfg.is_moe:
        return ["moe"], L, lead
    if cfg.global_attn_every and cfg.global_attn_every > 1:
        p = cfg.global_attn_every
        assert L % p == 0
        pat = ["attn_local"] * (p - 1) + ["attn_global"]
        return pat, L // p, lead
    return ["dense"], L, lead


# ----------------------------------------------------------------------
# Per-layer init / apply
# ----------------------------------------------------------------------
def _init_one_layer(key: jax.Array, kind: str, cfg: ModelConfig,
                    moe_spec: Optional[MoEBlockSpec], dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if kind == "mamba":
        p["mamba"] = M.init_mamba(ks[0], cfg, dtype)
        p["norm1"] = init_norm(cfg.d_model, cfg.norm)
        return p
    p["norm1"] = init_norm(cfg.d_model, cfg.norm)
    p["norm2"] = init_norm(cfg.d_model, cfg.norm)
    if cfg.post_norm:
        p["post_norm1"] = init_norm(cfg.d_model, cfg.norm)
        p["post_norm2"] = init_norm(cfg.d_model, cfg.norm)
    p["attn"] = A.init_attention(ks[0], cfg, dtype)
    if kind == "moe":
        p["moe"] = init_moe_params(ks[1], moe_spec, dtype)
        if cfg.moe.num_shared_experts:
            f_sh = cfg.moe.num_shared_experts * cfg.moe.d_ff_expert
            p["shared_mlp"] = init_mlp(ks[2], cfg.d_model, f_sh,
                                       "swiglu" if cfg.act == "swiglu"
                                       else cfg.act, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _apply_one_layer(x: jnp.ndarray, p: Dict[str, Any], kind: str,
                     cfg: ModelConfig, pcfg: ParallelConfig, *,
                     mode: str, q_offset, cache, cache_len,
                     moe_spec: Optional[MoEBlockSpec], mesh, skew_key,
                     causal: bool = True, constrain=lambda x, mode="none": x,
                     continue_prefill: bool = False,
                     valid_mask=None, block_table=None, block_size: int = 0,
                     moe_replica_ids=None, moe_residency_ids=None,
                     ) -> Tuple[jnp.ndarray, Any, Dict[str, jnp.ndarray]]:
    """One layer of any kind. Returns (x, new_cache, diag)."""
    diag: Dict[str, jnp.ndarray] = {}
    x = constrain(x, mode)
    if kind == "mamba":
        # a prefix valid_mask (padded final prefill chunk) becomes a per-row
        # valid length so pad tokens don't fold into the recurrent state
        vlen = valid_mask.sum(axis=1) if valid_mask is not None else None
        h, new_state = M.mamba_block(norm(x, p["norm1"], cfg.norm), p["mamba"],
                                     cfg, state=cache, valid_len=vlen)
        return x + h, new_state, diag

    is_global = (kind != "attn_local")
    h = norm(x, p["norm1"], cfg.norm)
    h, new_cache = A.attention_block(
        h, p["attn"], cfg, causal=causal, is_global=is_global,
        q_offset=q_offset, cache=cache, cache_len=cache_len,
        attn_chunk=pcfg.attn_chunk, use_pallas=pcfg.use_pallas,
        interpret=jax.default_backend() != "tpu",
        continue_prefill=continue_prefill,
        block_table=block_table, block_size=block_size,
        strict_pallas=pcfg.pallas_strict)
    if cfg.post_norm:
        h = norm(h, p["post_norm1"], cfg.norm)
    x = x + h

    h = norm(x, p["norm2"], cfg.norm)
    if kind == "moe":
        y, mdiag = moe_block(h, p["moe"], spec=moe_spec, mesh=mesh,
                             skew_key=skew_key, valid_mask=valid_mask,
                             replica_ids=moe_replica_ids,
                             residency_ids=moe_residency_ids)
        if "shared_mlp" in p:
            y = y + mlp(h, p["shared_mlp"],
                        "swiglu" if cfg.act == "swiglu" else cfg.act)
        # collapse the leading batch-shard dim only: scalar diags -> scalars,
        # vector diags (rank_load/expert_load) keep their trailing axis
        diag = {k: v.mean(axis=0) for k, v in mdiag.items()}
        h = y
    else:
        h = mlp(h, p["mlp"], cfg.act)
    if cfg.post_norm:
        h = norm(h, p["post_norm2"], cfg.norm)
    return x + h, new_cache, diag


# ----------------------------------------------------------------------
# Decoder stack (dense / moe / ssm patterns)
# ----------------------------------------------------------------------
def init_stack(key: jax.Array, cfg: ModelConfig,
               moe_spec: Optional[MoEBlockSpec], dtype) -> Dict[str, Any]:
    pattern, n_steps, lead = layer_pattern(cfg)
    params: Dict[str, Any] = {}
    key, *lead_keys = jax.random.split(key, lead + 1)
    if lead:
        dense_cfg_kind = "dense"
        params["lead"] = [
            _init_one_layer(k, dense_cfg_kind, cfg, None, dtype)
            for k in lead_keys]
    step_keys = jax.random.split(key, n_steps)
    def init_step(k):
        sub_keys = jax.random.split(k, len(pattern))
        return {f"sub{j}": _init_one_layer(sub_keys[j], pattern[j], cfg,
                                           moe_spec, dtype)
                for j in range(len(pattern))}
    params["blocks"] = jax.vmap(init_step)(step_keys)
    return params


def _layer_cache_init(kind: str, cfg: ModelConfig, batch: int, s_max: int,
                      dtype, clamp_window: bool = True) -> Any:
    if kind == "mamba":
        return M.init_state(batch, cfg, dtype)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    window = cfg.sliding_window
    if clamp_window and (kind == "attn_local"
                         or (window and not cfg.global_attn_every)):
        s_max = min(s_max, window)  # ring buffer for pure-SWA caches
    return A.AttnCache(jnp.zeros((batch, s_max, hkv, hd), dtype),
                       jnp.zeros((batch, s_max, hkv, hd), dtype))


def init_stack_cache(cfg: ModelConfig, batch: int, s_max: int, dtype,
                     clamp_window: bool = True) -> Dict[str, Any]:
    """``clamp_window=False`` keeps every attention leaf at full ``s_max``
    even for sliding-window layers (the serve engine's paged mode: windows
    are then enforced by ring-index arithmetic / masks, not by storage)."""
    pattern, n_steps, lead = layer_pattern(cfg)
    def one(kind):
        c = _layer_cache_init(kind, cfg, batch, s_max, dtype, clamp_window)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n_steps,) + x.shape), c)
    cache: Dict[str, Any] = {
        "blocks": {f"sub{j}": one(pattern[j]) for j in range(len(pattern))}}
    if lead:
        cache["lead"] = [_layer_cache_init("dense", cfg, batch, s_max, dtype,
                                           clamp_window)
                         for _ in range(lead)]
    return cache


def run_stack(x: jnp.ndarray, params: Dict[str, Any], cfg: ModelConfig,
              pcfg: ParallelConfig, *, mode: str,
              cache: Optional[Dict[str, Any]] = None,
              cache_len=None, q_offset=0,
              moe_spec: Optional[MoEBlockSpec] = None, mesh=None,
              skew_key=None, causal: bool = True, constrain=lambda x, mode="none": x,
              continue_prefill: bool = False, valid_mask=None,
              block_table=None, block_size: int = 0,
              moe_replica_ids=None, moe_residency_ids=None,
              moe_layer_diags: bool = False,
              ) -> Tuple[jnp.ndarray, Any, Dict[str, jnp.ndarray]]:
    """mode: train | prefill | decode | encode. Returns (x, new_cache, diags).

    ``moe_layer_diags`` (static) additionally emits ``expert_load_layers``
    [n_moe_steps, Ep] — the per-scan-step expert loads *before* the mean
    collapse — which the tiered-residency manager needs to predict each
    layer's working set separately (the per-layer signal from the PR-6
    follow-on)."""
    pattern, n_steps, lead = layer_pattern(cfg)

    new_lead_caches = []
    for i in range(lead):
        c = cache["lead"][i] if cache is not None else None
        x, nc, _ = _apply_one_layer(
            x, params["lead"][i], "dense", cfg, pcfg, mode=mode,
            q_offset=q_offset, cache=c, cache_len=cache_len,
            moe_spec=None, mesh=mesh, skew_key=skew_key, causal=causal,
            constrain=constrain, continue_prefill=continue_prefill,
            block_table=block_table, block_size=block_size)
        new_lead_caches.append(nc)

    def step(carry, inp):
        x, key = carry
        p_step, c_step = inp
        diags = {}
        new_caches = {}
        sub_key = key
        for j, kind in enumerate(pattern):
            if key is not None:
                sub_key = jax.random.fold_in(key, j)
            c = c_step[f"sub{j}"] if c_step is not None else None
            x, nc, d = _apply_one_layer(
                x, p_step[f"sub{j}"], kind, cfg, pcfg, mode=mode,
                q_offset=q_offset, cache=c, cache_len=cache_len,
                moe_spec=moe_spec, mesh=mesh, skew_key=sub_key, causal=causal,
                constrain=constrain, continue_prefill=continue_prefill,
                valid_mask=valid_mask, block_table=block_table,
                block_size=block_size, moe_replica_ids=moe_replica_ids,
                moe_residency_ids=moe_residency_ids)
            new_caches[f"sub{j}"] = nc
            diags.update({f"{k}": v for k, v in d.items()})
        new_key = (jax.random.fold_in(key, 997) if key is not None else None)
        return (x, new_key), (new_caches, diags)

    body = step
    if pcfg.remat != "none" and mode == "train":
        body = jax.checkpoint(step)

    xs_cache = cache["blocks"] if cache is not None else None
    if xs_cache is None:
        def wrapped(carry, p_step):
            return body(carry, (p_step, None))
        (x, _), (new_caches, diags) = jax.lax.scan(
            wrapped, (x, skew_key), params["blocks"])
    else:
        (x, _), (new_caches, diags) = jax.lax.scan(
            body, (x, skew_key), (params["blocks"], xs_cache))

    out_cache = None
    if cache is not None:
        out_cache = {"blocks": new_caches}
        if lead:
            out_cache["lead"] = new_lead_caches
    # scan stacks a leading n_steps axis; collapse it only, preserving the
    # trailing axis of vector diags (rank_load/expert_load)
    mean_diags = {k: v.mean(axis=0) for k, v in diags.items()}
    if moe_layer_diags and "expert_load" in diags:
        # the stacked pre-mean loads, one row per MoE scan step
        mean_diags["expert_load_layers"] = diags["expert_load"]
    return x, out_cache, mean_diags


# ----------------------------------------------------------------------
# Zamba2 hybrid stack: mamba backbone + shared attention blocks
# ----------------------------------------------------------------------
def init_hybrid(key: jax.Array, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    per = cfg.attn_every
    n_groups = cfg.num_layers // per
    rem = cfg.num_layers - n_groups * per
    k1, k2, k3 = jax.random.split(key, 3)

    def init_group(k):
        ks = jax.random.split(k, per)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[
            {"mamba": M.init_mamba(kk, cfg, dtype),
             "norm1": init_norm(cfg.d_model, cfg.norm)} for kk in ks])

    params = {"groups": jax.vmap(lambda k: init_group(k))(
        jax.random.split(k1, n_groups))}
    if rem:
        ks = jax.random.split(jax.random.fold_in(k1, 7), rem)
        params["tail"] = [
            {"mamba": M.init_mamba(kk, cfg, dtype),
             "norm1": init_norm(cfg.d_model, cfg.norm)} for kk in ks]
    # one SHARED attention(+MLP) block applied after every group
    params["shared"] = {
        "norm1": init_norm(cfg.d_model, cfg.norm),
        "norm2": init_norm(cfg.d_model, cfg.norm),
        "attn": A.init_attention(k2, cfg, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }
    return params


def init_hybrid_cache(cfg: ModelConfig, batch: int, s_max: int, dtype):
    per = cfg.attn_every
    n_groups = cfg.num_layers // per
    rem = cfg.num_layers - n_groups * per
    ms = M.init_state(batch, cfg, dtype)
    cache = {
        "mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups, per) + x.shape), ms),
        # each shared-attention application has its own KV cache
        "attn": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape),
            A.AttnCache(jnp.zeros((batch, s_max, cfg.num_kv_heads,
                                   cfg.resolved_head_dim), dtype),
                        jnp.zeros((batch, s_max, cfg.num_kv_heads,
                                   cfg.resolved_head_dim), dtype))),
    }
    if rem:
        cache["tail"] = [M.init_state(batch, cfg, dtype) for _ in range(rem)]
    return cache


def run_hybrid(x: jnp.ndarray, params, cfg: ModelConfig, pcfg: ParallelConfig,
               *, mode: str, cache=None, cache_len=None, q_offset=0,
               mesh=None, constrain=lambda x, mode="none": x,
               continue_prefill: bool = False,
               valid_mask=None) -> Tuple[jnp.ndarray, Any, Dict]:
    per = cfg.attn_every
    n_groups = cfg.num_layers // per
    rem = cfg.num_layers - n_groups * per
    shared = params["shared"]
    vlen = valid_mask.sum(axis=1) if valid_mask is not None else None

    def group_step(carry, inp):
        x = carry
        p_grp, c_grp = inp
        x = constrain(x, mode)
        new_m = []
        for i in range(per):
            p_i = jax.tree.map(lambda t: t[i], p_grp)
            c_i = (jax.tree.map(lambda t: t[i], c_grp["mamba"])
                   if c_grp is not None else None)
            h, nm = M.mamba_block(norm(x, p_i["norm1"], cfg.norm),
                                  p_i["mamba"], cfg, state=c_i,
                                  valid_len=vlen)
            x = x + h
            new_m.append(nm)
        # shared attention(+MLP) block — same weights every group
        c_a = c_grp["attn"] if c_grp is not None else None
        h = norm(x, shared["norm1"], cfg.norm)
        h, nc_a = A.attention_block(h, shared["attn"], cfg, causal=True,
                                    q_offset=q_offset, cache=c_a,
                                    cache_len=cache_len,
                                    attn_chunk=pcfg.attn_chunk,
                                    continue_prefill=continue_prefill)
        x = x + h
        x = x + mlp(norm(x, shared["norm2"], cfg.norm), shared["mlp"], cfg.act)
        new_cache = None
        if c_grp is not None:
            new_cache = {"mamba": jax.tree.map(lambda *t: jnp.stack(t), *new_m),
                         "attn": nc_a}
        return x, new_cache

    body = group_step
    if pcfg.remat != "none" and mode == "train":
        body = jax.checkpoint(group_step)

    if cache is None:
        x, _ = jax.lax.scan(lambda c, p: (body(c, (p, None))[0], None),
                            x, params["groups"])
        new_cache = None
    else:
        def wrapped(c, inp):
            return body(c, inp)
        x, stacked = jax.lax.scan(
            wrapped, x, (params["groups"],
                         {"mamba": cache["mamba"], "attn": cache["attn"]}))
        new_cache = {"mamba": stacked["mamba"], "attn": stacked["attn"]}

    new_tail = []
    for i in range(rem):
        c_i = cache["tail"][i] if cache is not None else None
        p_i = params["tail"][i]
        h, nt = M.mamba_block(norm(x, p_i["norm1"], cfg.norm), p_i["mamba"],
                              cfg, state=c_i, valid_len=vlen)
        x = x + h
        new_tail.append(nt)
    if cache is not None and rem:
        new_cache["tail"] = new_tail
    return x, new_cache, {}
