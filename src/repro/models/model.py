"""build_model(): unified functional model API for every assigned arch.

Returned ``Model`` exposes:
  init(key)                         -> params
  train_loss(params, batch, key)    -> (loss, diags)
  prefill(params, batch)            -> (logits [B, Vp], caches, pos)
  prefill_chunk(params, tokens, caches, pos) -> chunked-prefill continuation
  decode_step(params, token, caches, pos) -> (logits, caches)
      (pos may be a per-sequence [B] vector — slotted continuous batching;
       block_table= switches to the paged physical pool)
  input_specs(shape_kind)           -> pytree of ShapeDtypeStruct (dry-run)
  init_cache(batch, s_max)          -> decode caches (the serve slot pool)
  init_paged_cache(num_blocks, block_size) -> paged physical KV pool

The modality frontends are stubs per the assignment: whisper consumes
precomputed frame embeddings [B, 1500, d]; pixtral consumes precomputed patch
embeddings [B, n_patch, d] prepended to the token sequence.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.core.moe_layer import MoEBlockSpec
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.layers import (init_embedding, init_mlp, init_norm, mlp,
                                 norm, sinusoidal_positions)
from repro.models.losses import chunked_softmax_xent, logits_head


@dataclass(frozen=True)
class MeshShape:
    """Static mesh info the model needs (sizes + axis names)."""
    axes: Tuple[Tuple[str, int], ...] = (("data", 1), ("model", 1))

    @property
    def sizes(self) -> Dict[str, int]:
        return dict(self.axes)

    @property
    def ep_degree(self) -> int:
        return self.sizes.get("model", 1)

    def batch_axes(self, global_batch: int) -> Tuple[str, ...]:
        """Largest prefix of (pod, data) that divides the batch."""
        cand = [a for a in ("pod", "data") if a in self.sizes]
        chosen: Tuple[str, ...] = ()
        prod = 1
        for a in cand:
            if global_batch % (prod * self.sizes[a]) == 0:
                chosen += (a,)
                prod *= self.sizes[a]
        return chosen

    def batch_shards(self, global_batch: int) -> int:
        prod = 1
        for a in self.batch_axes(global_batch):
            prod *= self.sizes[a]
        return prod


@dataclass
class Model:
    cfg: ModelConfig
    pcfg: ParallelConfig
    mesh_shape: MeshShape
    batch: int
    seq_len: int
    init: Callable[..., Any] = None
    train_loss: Callable[..., Any] = None
    prefill: Callable[..., Any] = None
    prefill_chunk: Callable[..., Any] = None
    decode_step: Callable[..., Any] = None
    init_cache: Callable[..., Any] = None
    init_paged_cache: Callable[..., Any] = None
    input_specs: Callable[..., Any] = None
    moe_spec: Optional[MoEBlockSpec] = None


def _dtype_of(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def build_model(cfg: ModelConfig, pcfg: ParallelConfig, *, batch: int,
                seq_len: int, mesh_shape: MeshShape = MeshShape(),
                mesh: Optional[jax.sharding.Mesh] = None) -> Model:
    dtype = _dtype_of(cfg)
    Vp = cfg.padded_vocab
    d = cfg.d_model
    b_shards = mesh_shape.batch_shards(batch)
    b_local = batch // b_shards
    batch_axes = mesh_shape.batch_axes(batch)

    # Activation batch constraint: pins [B, ...] activations to the batch
    # axes so XLA resolves FSDP conflicts by all-gathering weights (the
    # intended ZeRO-3 dataflow) instead of replicating activations.
    ep = mesh_shape.ep_degree
    # SP policy (EXPERIMENTS.md §Perf 1.2): in train mode, remat re-pays
    # every SP->TP all-gather, so SP is only worth it when attention heads
    # cannot TP-shard (then seq is the only parallelism for attention math).
    sp_train = (cfg.num_heads % ep != 0) if cfg.num_heads else False

    def constrain(x, mode: str = "none"):
        if mesh is None or "data" not in mesh.axis_names:
            return x
        # sequence parallelism: the residual stream is sharded over 'model'
        # between attention/MoE blocks (norm/elementwise work and workspace
        # divide by ep); XLA inserts the all-gather where TP weights need the
        # full sequence.
        seq = mode in ("prefill", "encode") or (mode == "train" and sp_train)
        seq_spec = "model" if (seq and x.ndim == 3 and ep > 1
                               and x.shape[1] % ep == 0) else None
        spec = jax.sharding.PartitionSpec(
            *([batch_axes if batch_axes else None, seq_spec]
              + [None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))

    moe_spec = None
    if cfg.is_moe:
        moe_spec = MoEBlockSpec(
            moe=cfg.moe, d_model=d, ep_axis="model", batch_axes=batch_axes,
            ep_degree=mesh_shape.ep_degree,
            # per-STEP tokens: microbatching divides the batch per grad step
            tokens_local=max(b_local // max(pcfg.microbatch, 1), 1) * seq_len,
            act="silu" if cfg.act == "swiglu" else "gelu",
            cf_pair=pcfg.moe_cf_pair,
            block_m=pcfg.moe_block_m,
            use_pallas=pcfg.use_pallas,
            interpret=jax.default_backend() != "tpu",
            tp_mode=cfg.moe.num_experts < mesh_shape.ep_degree,
            seq_sharded=(seq_len % mesh_shape.ep_degree == 0
                         and mesh_shape.ep_degree > 1))

    # MoE decode uses a separate spec sized for one token per sequence.
    # Foreign slots at decode depend on the policy:
    #   * even_split schedules units for EVERY expert to EVERY rank, so each
    #     rank needs a group per non-local expert (with K = 0 those units
    #     have nowhere to land and are counted as drops);
    #   * harmoeny keeps the configured K so serving-time redistribution can
    #     move hot-expert load to non-host ranks (paper Alg. 2 at decode);
    #   * round_robin / static_opt never leave the initial placement.
    def _decode_foreign_slots(policy: str) -> int:
        if moe_spec.tp_mode:
            return 0
        topo = moe_spec.topo
        if policy == "even_split":
            return topo.padded_experts - topo.experts_per_rank
        if policy == "harmoeny":
            return cfg.moe.num_foreign_slots
        return 0

    moe_spec_decode = None
    if cfg.is_moe:
        moe_spec_decode = dataclasses.replace(
            moe_spec,
            tokens_local=b_local,
            seq_sharded=False,
            block_m=128,   # decode batches are tiny; big tiles = pure padding
            moe=dataclasses.replace(
                cfg.moe,
                num_foreign_slots=_decode_foreign_slots(cfg.moe.policy)))

    is_encdec = cfg.is_encoder_decoder
    n_prefix = cfg.num_prefix_embeddings

    # ------------------------------------------------------------------
    def init(key: jax.Array):
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": init_embedding(ks[0], Vp, d, dtype),
            "final_norm": init_norm(d, cfg.norm),
            "stack": (T.init_hybrid(ks[1], cfg, dtype)
                      if cfg.family == "hybrid"
                      else T.init_stack(ks[1], cfg, moe_spec, dtype)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(ks[2], Vp, d, dtype)
        if is_encdec:
            enc_cfg = dataclasses.replace(
                cfg, num_layers=cfg.encoder_layers, is_encoder_decoder=False)
            params["encoder"] = {
                "stack": T.init_stack(ks[3], enc_cfg, None, dtype),
                "final_norm": init_norm(d, cfg.norm),
            }
            params["cross"] = _init_cross_layers(ks[4], cfg, dtype)
        return params

    # ------------------------------------------------------------------
    def _backbone(params, h, *, mode, cache=None, cache_len=None,
                  q_offset=0, spec=None, skew_key=None, enc_out=None,
                  continue_prefill=False, valid_mask=None,
                  block_table=None, block_size=0, pcfg_run=None,
                  moe_replica_ids=None, moe_residency_ids=None,
                  moe_layer_diags=False):
        pc = pcfg_run if pcfg_run is not None else pcfg
        h = constrain(h, mode)
        if block_table is not None and (cfg.family == "hybrid" or is_encdec):
            raise NotImplementedError(
                "paged KV decode supports plain decoder stacks only")
        if cfg.family == "hybrid":
            h, new_cache, diags = T.run_hybrid(
                h, params["stack"], cfg, pc, mode=mode, cache=cache,
                cache_len=cache_len, q_offset=q_offset, mesh=mesh,
                constrain=constrain, continue_prefill=continue_prefill,
                valid_mask=valid_mask)
        elif is_encdec:
            h, new_cache, diags = _run_encdec_decoder(
                h, params, cfg, pc, mode=mode, cache=cache,
                cache_len=cache_len, q_offset=q_offset, enc_out=enc_out,
                constrain=constrain)
        else:
            h, new_cache, diags = T.run_stack(
                h, params["stack"], cfg, pc, mode=mode, cache=cache,
                cache_len=cache_len, q_offset=q_offset,
                moe_spec=spec, mesh=mesh, skew_key=skew_key,
                constrain=constrain, continue_prefill=continue_prefill,
                valid_mask=valid_mask, block_table=block_table,
                block_size=block_size, moe_replica_ids=moe_replica_ids,
                moe_residency_ids=moe_residency_ids,
                moe_layer_diags=moe_layer_diags)
        h = norm(h, params["final_norm"], cfg.norm)
        return h, new_cache, diags

    def _encode(params, frames):
        """Whisper encoder over stubbed frame embeddings [B, S_enc, d]."""
        enc_cfg = dataclasses.replace(
            cfg, num_layers=cfg.encoder_layers, is_encoder_decoder=False)
        pos = sinusoidal_positions(frames.shape[1], d).astype(frames.dtype)
        h = frames + pos[None]
        h, _, _ = T.run_stack(h, params["encoder"]["stack"], enc_cfg,
                              dataclasses.replace(pcfg),
                              mode="encode", moe_spec=None, mesh=mesh,
                              causal=False)
        return norm(h, params["encoder"]["final_norm"], cfg.norm)

    def _embed_tokens(params, tokens, offset=0):
        h = params["embed"][tokens]
        if cfg.rope_theta <= 0 and cfg.ssm is None:  # absolute pos (whisper)
            table = sinusoidal_positions(seq_len + 65, d).astype(h.dtype)
            S = tokens.shape[1]
            off = jnp.asarray(offset, jnp.int32)
            if off.ndim:  # per-sequence offsets (slotted decode)
                h = h + table[off[:, None] + jnp.arange(S)[None]]
            else:
                pos_emb = jax.lax.dynamic_slice_in_dim(table, off, S, axis=0)
                h = h + pos_emb[None]
        if cfg.name.startswith("gemma"):
            h = h * jnp.asarray(d ** 0.5, h.dtype)
        return h

    def _vocab_w(params):
        return params["embed"] if cfg.tie_embeddings else params["lm_head"]

    # ------------------------------------------------------------------
    def train_loss(params, batch_in, skew_key=None):
        tokens, labels = batch_in["tokens"], batch_in["labels"]
        h = _embed_tokens(params, tokens)
        enc_out = None
        if is_encdec:
            enc_out = _encode(params, batch_in["frames"])
        if n_prefix:
            h = jnp.concatenate(
                [batch_in["patches"].astype(h.dtype), h], axis=1)
        h, _, diags = _backbone(params, h, mode="train", spec=moe_spec,
                                skew_key=skew_key, enc_out=enc_out)
        if n_prefix:
            h = h[:, n_prefix:]
        loss = chunked_softmax_xent(
            h, _vocab_w(params), labels, real_vocab=cfg.vocab_size,
            chunk=pcfg.loss_chunk, softcap=cfg.final_logit_softcap)
        if "aux_loss" in diags:
            loss = loss + 0.01 * diags["aux_loss"]
        return loss, diags

    # ------------------------------------------------------------------
    def init_cache(b: int, s_max: int, clamp_window: bool = True):
        cache: Dict[str, Any] = {}
        if cfg.family == "hybrid":
            cache["stack"] = T.init_hybrid_cache(cfg, b, s_max, dtype)
        else:
            cache["stack"] = T.init_stack_cache(cfg, b, s_max, dtype,
                                                clamp_window)
        if is_encdec:
            # encoder K/V per decoder layer; contents filled by prefill
            hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            z = jnp.zeros((cfg.num_layers, b, cfg.encoder_seq_len, hkv, hd),
                          dtype)
            cache["cross"] = A.AttnCache(z, z)
        return cache

    def init_paged_cache(num_blocks: int, block_size: int,
                         s_ref: Optional[int] = None, seq_axes: Any = None,
                         clamp_window: bool = True):
        """Paged variant of ``init_cache``: a batch-1 *physical* block pool
        of ``num_blocks * block_size`` KV positions per leaf, addressed
        through block tables in ``decode_step``.  ``s_ref`` (default the
        model's ``seq_len``) is the logical length the layout is validated
        at — every leaf must expose a full, unclamped KV axis there.
        ``seq_axes`` skips re-discovery when the caller (the serve engine)
        already holds the per-leaf KV-axis pytree.  ``clamp_window=False``
        builds the pool over unclamped (full-length) leaves — the serve
        engine's sliding-window ring mode."""
        from repro.serve.paging import make_paged_pool
        from repro.serve.slots import discover_seq_axes
        s = s_ref or seq_len

        def _ic(b, s_max):
            return init_cache(b, s_max, clamp_window)
        if seq_axes is None:
            seq_axes = discover_seq_axes(_ic, s)
        return make_paged_pool(_ic, s, seq_axes, num_blocks, block_size)

    def prefill(params, batch_in, s_max: Optional[int] = None):
        tokens = batch_in["tokens"]
        B, S = tokens.shape
        s_max = s_max or (S + 64)
        h = _embed_tokens(params, tokens)
        enc_out = None
        if is_encdec:
            enc_out = _encode(params, batch_in["frames"])
        if n_prefix:
            h = jnp.concatenate(
                [batch_in["patches"].astype(h.dtype), h], axis=1)
        cache = init_cache(B, s_max)
        pos = jnp.int32(h.shape[1])
        h, new_cache, diags = _backbone(
            params, h, mode="prefill", cache=cache["stack"],
            cache_len=pos, spec=moe_spec, enc_out=enc_out,
            skew_key=batch_in.get("skew_key"))
        out_cache = {"stack": new_cache}
        if is_encdec:
            out_cache["cross"] = _cross_kv(params, enc_out, cfg)
        logits = logits_head(h[:, -1], _vocab_w(params),
                             real_vocab=cfg.vocab_size,
                             softcap=cfg.final_logit_softcap)
        return logits, out_cache, pos, diags

    def prefill_chunk(params, tokens, caches, pos, last_index=None,
                      skew_key=None, moe_replica_ids=None,
                      fused_attention=None, fused_moe=None):
        """Chunked-prefill continuation for the serving engine.

        tokens [Bc, C] is the next prompt chunk, appended to ``caches`` at
        position ``pos`` (scalar — all Bc rows share the offset). Returns
        (logits, caches, pos + C, diags) where logits are taken at
        ``last_index`` within the chunk (default C - 1); pad the final chunk
        to C and pass the true last-token index. The caller owns position
        bookkeeping for partially-filled final chunks.
        ``fused_attention`` (static) overrides ``pcfg.use_pallas`` for this
        chunk's attention blocks — the q-tiled paged kernel then runs the
        whole chunk over the slab scratch (strict: an inapplicable fused
        path raises instead of silently falling back); ``fused_moe``
        (static) overrides the MoE spec's ``use_pallas`` so the chunk's
        Bc * C expert tokens go through the grouped-GEMM Pallas kernel —
        both wired by the serve engine without rebuilding the model.
        """
        Bc, C = tokens.shape
        spec = moe_spec
        if spec is not None:
            spec = dataclasses.replace(
                spec, tokens_local=Bc * C,
                seq_sharded=(C % mesh_shape.ep_degree == 0
                             and mesh_shape.ep_degree > 1))
            if fused_moe is not None:
                spec = dataclasses.replace(spec, use_pallas=bool(fused_moe))
        h = _embed_tokens(params, tokens, offset=pos)
        new_pos = pos + C
        # pad tokens beyond last_index are dead: keep them out of MoE
        # routing/capacity (their K/V writes are masked by cache_len anyway)
        # and out of SSM recurrent-state updates (state has no cache_len to
        # mask behind — pad tokens would fold in permanently)
        vmask = None
        if (cfg.is_moe or cfg.ssm is not None) and last_index is not None:
            li = jnp.asarray(last_index, jnp.int32)
            vmask = jnp.arange(C)[None, :] <= (li[..., None] if li.ndim
                                               else li)
            vmask = jnp.broadcast_to(vmask, (Bc, C))
        pcfg_step = None
        if fused_attention is not None:
            pcfg_step = dataclasses.replace(
                pcfg, use_pallas=bool(fused_attention),
                pallas_strict=bool(fused_attention))
        h, new_stack, diags = _backbone(
            params, h, mode="prefill", cache=caches["stack"],
            cache_len=new_pos, q_offset=pos, spec=spec, skew_key=skew_key,
            continue_prefill=True, valid_mask=vmask,
            pcfg_run=pcfg_step, moe_replica_ids=moe_replica_ids)
        idx = jnp.asarray(C - 1 if last_index is None else last_index,
                          jnp.int32)
        if idx.ndim:
            hl = h[jnp.arange(Bc), idx]
        else:
            hl = jax.lax.dynamic_index_in_dim(h, idx, axis=1, keepdims=False)
        logits = logits_head(hl, _vocab_w(params),
                             real_vocab=cfg.vocab_size,
                             softcap=cfg.final_logit_softcap)
        out = dict(caches)
        out["stack"] = new_stack
        return logits, out, new_pos, diags

    def decode_step(params, token, caches, pos, skew_key=None,
                    active_mask=None, block_table=None, block_size=0,
                    fused_attention=None, fused_moe=None, moe_policy=None,
                    moe_replica_ids=None, moe_residency_ids=None,
                    moe_layer_diags=False):
        """token [B, S] int32 (S = 1 is plain decode; S = k + 1 is a
        speculative-verify window, paged only); pos = current length BEFORE
        appending the window (scalar, or a per-sequence [B] vector for
        slotted batches) — window position i lands at ``pos + i``.
        ``active_mask`` [B] bool excludes vacated slots' garbage tokens from
        MoE routing and capacity (their logits are garbage either way).
        ``block_table`` [B, max_blocks_per_slot] switches the cache to a
        paged physical pool (``caches`` from ``init_paged_cache``): K/V
        writes and attention gathers go through each row's block chain,
        causal within the window when S > 1.
        ``fused_attention`` (static, paged mode only) overrides
        ``pcfg.use_pallas`` for this step's attention blocks (strict: an
        inapplicable fused path raises instead of silently falling back)
        and ``fused_moe`` (static) overrides the MoE spec's ``use_pallas``
        (grouped-GEMM Pallas expert FFN for the B or B * S routed tokens),
        letting the serve engine opt into the fused kernels without
        rebuilding the model.
        ``moe_policy`` (static) overrides the decode-path scheduling policy
        for this step; ``moe_replica_ids`` [G, R] (traced, -1 = empty) names
        the experts occupying the replica slots — both wired by the serve
        engine (EngineConfig.moe_policy / serve/rebalance.py).
        ``moe_residency_ids`` [G, W] (traced, -1 = pad) is the tiered
        residency table (serve/residency.py): each rank's HBM-resident
        working set, demoting swapped-out experts in the schedule;
        ``moe_layer_diags`` (static) emits the per-layer
        ``expert_load_layers`` diagnostic the residency predictor consumes.

        Returns logits [B, Vp] at the last position when S == 1, else
        [B, S, Vp] at every window position (the verify step scores all
        drafted continuations in one pass)."""
        B, S = token.shape
        if S > 1 and block_table is None:
            raise NotImplementedError(
                "multi-token decode (speculative verify) goes through the "
                "paged pool: pass block_table/block_size")
        h = _embed_tokens(params, token, offset=pos)
        new_pos = pos + S
        vmask = None
        if cfg.is_moe and active_mask is not None:
            am = jnp.asarray(active_mask).reshape(-1, 1)       # [B, 1]
            vmask = jnp.broadcast_to(am, (B, S)) if S > 1 else am
        spec_dec = moe_spec_decode
        if spec_dec is not None and moe_policy is not None \
                and moe_policy != spec_dec.moe.policy:
            spec_dec = dataclasses.replace(
                spec_dec, moe=dataclasses.replace(
                    spec_dec.moe, policy=moe_policy,
                    num_foreign_slots=_decode_foreign_slots(moe_policy)))
        if spec_dec is not None and S > 1:
            # the verify window routes B * S tokens per step, not B
            spec_dec = dataclasses.replace(
                spec_dec, tokens_local=spec_dec.tokens_local * S)
        if spec_dec is not None and fused_moe is not None:
            spec_dec = dataclasses.replace(
                spec_dec, use_pallas=bool(fused_moe))
        pcfg_step = None
        if fused_attention is not None and block_table is not None:
            pcfg_step = dataclasses.replace(
                pcfg, use_pallas=bool(fused_attention),
                pallas_strict=bool(fused_attention))
        h, new_stack, diags = _backbone(
            params, h, mode="decode", cache=caches["stack"],
            cache_len=new_pos, q_offset=pos, spec=spec_dec,
            skew_key=skew_key,
            enc_out=caches.get("cross"), valid_mask=vmask,
            block_table=block_table, block_size=block_size,
            pcfg_run=pcfg_step, moe_replica_ids=moe_replica_ids,
            moe_residency_ids=moe_residency_ids,
            moe_layer_diags=moe_layer_diags)
        if S == 1:
            logits = logits_head(h[:, -1], _vocab_w(params),
                                 real_vocab=cfg.vocab_size,
                                 softcap=cfg.final_logit_softcap)
        else:
            logits = logits_head(h.reshape(B * S, -1), _vocab_w(params),
                                 real_vocab=cfg.vocab_size,
                                 softcap=cfg.final_logit_softcap)
            logits = logits.reshape(B, S, -1)
        out = dict(caches)
        out["stack"] = new_stack
        return logits, out, new_pos, diags

    # ------------------------------------------------------------------
    def input_specs(kind: str):
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        tok = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        specs: Dict[str, Any] = {"tokens": tok}
        if kind == "train":
            specs["labels"] = tok
        if is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq_len, d), dtype)
        if n_prefix:
            specs["patches"] = jax.ShapeDtypeStruct((batch, n_prefix, d), dtype)
        if kind == "decode":
            specs["tokens"] = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        return specs

    return Model(cfg=cfg, pcfg=pcfg, mesh_shape=mesh_shape, batch=batch,
                 seq_len=seq_len, init=init, train_loss=train_loss,
                 prefill=prefill, prefill_chunk=prefill_chunk,
                 decode_step=decode_step,
                 init_cache=init_cache, init_paged_cache=init_paged_cache,
                 input_specs=input_specs,
                 moe_spec=moe_spec)


# ----------------------------------------------------------------------
# Whisper-style cross-attention decoder
# ----------------------------------------------------------------------
def _init_cross_layers(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, cfg.num_layers)

    def one(k):
        k1, _ = jax.random.split(k)
        return {"norm": init_norm(cfg.d_model, cfg.norm),
                "attn": A.init_attention(k1, cfg, dtype)}
    return jax.vmap(one)(ks)


def _cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute encoder K/V for every decoder layer at prefill."""
    def one(p_cross):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["attn"]["wv"])
        return A.AttnCache(k, v)
    return jax.vmap(one)(params["cross"])


def _run_encdec_decoder(h, params, cfg: ModelConfig, pcfg, *, mode, cache,
                        cache_len, q_offset, enc_out, constrain=lambda x, seq=False: x):
    """Decoder stack with interleaved cross-attention (scan over layers)."""
    n = cfg.num_layers
    blocks = params["stack"]["blocks"]
    cross = params["cross"]

    # cross K/V: computed from enc_out at train/prefill; at decode enc_out is
    # the precomputed AttnCache pytree (stacked per layer)
    if mode == "decode":
        cross_kv = enc_out
    else:
        cross_kv = _cross_kv(params, enc_out, cfg)

    def step(carry, inp):
        x = carry
        p_step, c_step, p_cross, ckv = inp
        p = p_step["sub0"]
        c = c_step["sub0"] if c_step is not None else None
        x = constrain(x, mode)
        # self-attention
        hh = norm(x, p["norm1"], cfg.norm)
        hh, nc = A.attention_block(hh, p["attn"], cfg, causal=True,
                                   q_offset=q_offset, cache=c,
                                   cache_len=cache_len,
                                   attn_chunk=pcfg.attn_chunk)
        x = x + hh
        # cross-attention against fixed encoder K/V
        hh = norm(x, p_cross["norm"], cfg.norm)
        q = jnp.einsum("bsd,dhk->bshk", hh, p_cross["attn"]["wq"])
        out = A.chunked_attention(q, ckv.k, ckv.v, causal=False,
                                  chunk=pcfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", out, p_cross["attn"]["wo"])
        # mlp
        x = x + mlp(norm(x, p["norm2"], cfg.norm), p["mlp"], cfg.act)
        return x, (nc,)

    body = step
    if pcfg.remat != "none" and mode == "train":
        body = jax.checkpoint(step)

    c_blocks = cache["blocks"] if cache is not None else None
    if c_blocks is None:
        def wrapped(carry, inp):
            p_step, p_cross, ckv = inp
            x, (nc,) = body(carry, (p_step, None, p_cross, ckv))
            return x, nc
        x, _ = jax.lax.scan(wrapped, h, (blocks, cross, cross_kv))
        return x, None, {}

    def wrapped2(carry, inp):
        x, (nc,) = body(carry, inp)
        return x, nc
    x, ncs = jax.lax.scan(wrapped2, h, (blocks, c_blocks, cross, cross_kv))
    return x, {"blocks": {"sub0": ncs}}, {}
