"""Vocab-sharded chunked cross-entropy.

Never materializes the full [B, S, V] logits tensor: scans the sequence in
chunks, computing logits -> logsumexp -> label logit per chunk. Decisive for
256k-vocab archs (gemma2) at train_4k (DESIGN.md §Perf). The vocab dim of
``w_vocab`` is sharded over 'model'; XLA partitions the chunk matmul and the
logsumexp reduction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def chunked_softmax_xent(hidden: jnp.ndarray, w_vocab: jnp.ndarray,
                         labels: jnp.ndarray, *, real_vocab: int,
                         chunk: int = 2048, softcap: float = 0.0,
                         ignore_id: int = -1) -> jnp.ndarray:
    """hidden [B, S, d]; w_vocab [Vp, d]; labels [B, S] -> mean NLL."""
    B, S, d = hidden.shape
    Vp = w_vocab.shape[0]
    chunk = min(chunk, S)
    n = -(-S // chunk)
    Sp = n * chunk
    if Sp != S:
        hidden = jnp.pad(hidden, ((0, 0), (0, Sp - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, Sp - S)),
                         constant_values=ignore_id)
    hc = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    vocab_mask = jnp.arange(Vp) < real_vocab

    def step(carry, inp):
        nll_sum, count = carry
        h, lab = inp
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                            w_vocab.astype(jnp.float32))
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where(vocab_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit via masked reduce, NOT take_along_axis: the vocab dim is
        # sharded over 'model' and a gather there would force XLA to
        # all-gather the full logits chunk (GBs); the masked sum stays sharded
        # and lowers to a partial reduce + tiny all-reduce.
        lab_c = jnp.clip(lab, 0, Vp - 1)
        onehot = (jnp.arange(Vp)[None, None, :] == lab_c[..., None])
        lab_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        valid = (lab != ignore_id)
        nll = jnp.where(valid, lse - lab_logit, 0.0)
        return (nll_sum + nll.sum(), count + valid.sum()), None

    # checkpoint: the [B, chunk, V] logits block is recomputed in backward
    # rather than stored per chunk (chunked-CE-with-recompute)
    (nll_sum, count), _ = jax.lax.scan(
        jax.checkpoint(step), (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return nll_sum / jnp.maximum(count, 1)


def logits_head(hidden_last: jnp.ndarray, w_vocab: jnp.ndarray, *,
                real_vocab: int, softcap: float = 0.0) -> jnp.ndarray:
    """hidden_last [B, d] -> logits [B, Vp] (padded vocab masked)."""
    logits = jnp.einsum("bd,vd->bv", hidden_last.astype(jnp.float32),
                        w_vocab.astype(jnp.float32))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    Vp = w_vocab.shape[0]
    return jnp.where(jnp.arange(Vp)[None, :] < real_vocab, logits, -1e30)
