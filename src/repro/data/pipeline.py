"""Token data pipeline: the paper's synthetic datasets + a prefetching loader.

Datasets (paper §5.1.2):
  * random   — seeded uniform random token sequences (identical across runs);
  * constant — a single token repeated (maximal router determinism);
  * zipf     — a heavy-tailed surrogate for the real-corpus token skews of
               BookCorpus/WikiText/WMT19 (which cannot ship in an offline
               container); the Zipf exponent is calibrated so the induced
               expert ECDF matches the paper's Figure 1 shape (~50% of mass
               on a handful of experts).

The loader prefetches batches on a host thread (straggler mitigation for the
input stage: device steps never wait on host tokenization).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class ByteTokenizer:
    """Deterministic byte-level tokenizer (for real-text examples)."""

    vocab_size = 256 + 2
    bos, eos = 256, 257

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        b = bytes(int(i) for i in ids if int(i) < 256)
        return b.decode("utf-8", errors="replace")


def synthetic_batches(kind: str, *, batch: int, seq_len: int, vocab: int,
                      seed: int = 0, zipf_a: float = 1.3
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens, labels} batches."""
    rng = np.random.default_rng(seed)
    while True:
        if kind == "random":
            toks = rng.integers(0, vocab, (batch, seq_len + 1), dtype=np.int64)
        elif kind == "constant":
            toks = np.full((batch, seq_len + 1), 7, np.int64)
        elif kind == "zipf":
            toks = rng.zipf(zipf_a, (batch, seq_len + 1)) % vocab
        else:
            raise ValueError(kind)
        toks = toks.astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchLoader:
    """Host-thread prefetch queue around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
