"""Fault-tolerance runtime: failure injection, resume orchestration, and the
straggler-mitigation story.

At 1000+ node scale the failure model is: any worker can die at any step; the
job restarts (same or reduced mesh) and must resume bit-exact from the last
committed checkpoint. The pieces here + checkpoint/checkpointer.py implement
that contract; tests/test_fault_tolerance.py kills a training loop mid-run
and verifies the resumed loss trajectory matches an uninterrupted run.

Straggler mitigation layers (DESIGN.md §3):
  * token level  — the HarMoEny scheduler itself: the max-loaded EP rank
    bounds the MoE layer's critical path, and rebalancing minimizes it;
  * input level  — host-thread prefetch (data/pipeline.py);
  * step level   — XLA SPMD is lockstep; persistent stragglers are handled
    by restart-with-smaller-mesh (elastic re-shard on restore).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically kill the loop at a given step (tests/examples)."""
    fail_at_step: Optional[int] = None

    @staticmethod
    def from_env() -> "FailureInjector":
        v = os.environ.get("REPRO_FAIL_AT_STEP")
        return FailureInjector(int(v) if v else None)

    def check(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_restarts(make_loop: Callable[[], int], *, max_restarts: int = 3
                      ) -> int:
    """Drive a resumable loop through injected/real failures.

    ``make_loop`` runs training from the latest checkpoint and returns the
    final step; on failure it is re-invoked (fresh process state would be the
    real-cluster equivalent)."""
    attempts = 0
    while True:
        try:
            return make_loop()
        except InjectedFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
