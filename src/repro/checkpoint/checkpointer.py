"""Fault-tolerant checkpointing: sharded npz + manifest, async save, keep-k,
CRC integrity, resume-from-latest, and elastic re-sharding.

Layout:  <dir>/step_<N>/
           manifest.json   {step, leaf paths, shapes, dtypes, crc32, mesh}
           leaf_<i>.npy    one array per pytree leaf (np.save)
         <dir>/step_<N>.done   commit marker (atomic rename)

A checkpoint without its ``.done`` marker is treated as torn and ignored by
``latest_step`` — this is what makes kill-at-any-point restarts safe. Saves
run on a background thread (training never blocks on disk). Elastic restart:
``restore`` takes the *current* mesh/shardings and re-shards on load via
jax.device_put, so a checkpoint written on one mesh restores onto another
(tested 2x4 -> 4x2 and 8 -> 4 in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot to host then write on a background thread."""
        leaves, treedef = _leaf_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"_tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            for i, arr in enumerate(host_leaves):
                path = os.path.join(tmp, f"leaf_{i}.npy")
                np.save(path, arr)
                manifest["leaves"].append({
                    "i": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                })
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            # commit marker LAST: torn writes are never visible
            with open(final + ".done", "w") as f:
                f.write("ok")
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".done"):
                steps.append(int(name[len("step_"):-len(".done")]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, *,
                shardings: Any = None, verify: bool = True) -> Any:
        """Load step into the structure of ``like``; re-shard onto
        ``shardings`` (a pytree of NamedSharding matching ``like``) — this is
        the elastic-restart path when the mesh changed."""
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _leaf_paths(like)
        assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for meta, ref, shd in zip(manifest["leaves"], leaves, shard_leaves):
            arr = np.load(os.path.join(final, f"leaf_{meta['i']}.npy"))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint leaf {meta['i']} corrupt "
                                  f"(crc {crc} != {meta['crc32']})")
            if shd is not None:
                arr = jax.device_put(arr, shd)
            out.append(arr)
        return treedef.unflatten(out)

    def restore_latest(self, like: Any, *, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings=shardings)

    # ------------------------------------------------------------------
    def _gc(self):
        steps = sorted(
            int(n[len("step_"):-len(".done")])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and n.endswith(".done"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, f"step_{s}.done"))
            except OSError:
                pass
