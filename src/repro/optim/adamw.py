"""AdamW in pure JAX (no optax dependency in this container)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.int32(0), zeros,
                      jax.tree.map(jnp.zeros_like, zeros))


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)
