"""Int8-compressed gradient all-reduce with error feedback.

Distributed-optimization trick for the train path: gradients are quantized
to int8 (per-leaf scale, stochastic rounding) before the data-parallel
all-reduce, cutting cross-pod gradient bytes 4x (bf32->int8). The
quantization residual is carried in an error-feedback buffer so the scheme
is unbiased over steps (Karimireddy et al. style).

Used via ``compressed_psum(grads, axis, err)`` inside shard_map, or the
``quantize/dequantize`` pair directly in pjit-land tests.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key: jax.Array) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    scaled = x / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, err: Any, key: jax.Array, *,
                    axis_name: str) -> Tuple[Any, Any]:
    """Per-leaf int8 all-reduce with a SHARED scale: a scalar pmax of the
    abs-max fixes one quantization grid across ranks (a per-rank scale would
    bias the sum), then the int8 payload is summed and dequantized. Returns
    (mean grads, new error-feedback buffers)."""
    leaves, tdef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(err)
    n = jax.lax.psum(1, axis_name)
    outs, new_errs = [], []
    for i, (g, e) in enumerate(zip(leaves, err_leaves)):
        k = jax.random.fold_in(key, i)
        g32 = g.astype(jnp.float32) + e
        local_max = jnp.abs(g32).max()
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        noise = jax.random.uniform(k, g32.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(g32 / scale + noise), -127, 127).astype(jnp.int8)
        new_errs.append(g32 - q.astype(jnp.float32) * scale)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        outs.append(summed.astype(jnp.float32) * scale / n)
    return tdef.unflatten(outs), tdef.unflatten(new_errs)
