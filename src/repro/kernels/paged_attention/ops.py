"""Jitted wrapper used by models/attention.py (layout adaptation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import \
    paged_attention_kernel


def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, cache_len: jnp.ndarray, *,
                    block_size: int, softcap: float = 0.0,
                    interpret: bool = False) -> jnp.ndarray:
    """Model-layout entry: q [B, S, H, hd] with S >= 1 query positions
    (S = 1 is plain decode; S = k + 1 is a speculative-verify window,
    causal within the window); k_pool/v_pool [1, P, Hkv, hd] *physical*
    pools with P = num_blocks * block_size (the serve engine's paged
    cache leaves); block_table [B, n_blocks] int32; cache_len scalar or
    per-row [B] — the total valid length INCLUDING the S window positions
    (query i sits at absolute position ``cache_len - S + i``)
    -> [B, S, H, hd].

    The pool's KV axis is viewed as [num_blocks, block_size] (pure
    reshape, no copy) and q as [B, Hkv, S * rep, hd] (query i, q head
    h = g * rep + r at row i * rep + r — the ``_repeat_kv`` head order per
    query), so the kernel can index whole physical blocks and handle GQA
    and the query window in its index maps and mask.
    """
    B, S, H, hd = q.shape
    P, Hkv = k_pool.shape[1], k_pool.shape[2]
    rep = H // Hkv
    num_blocks = P // block_size
    assert num_blocks * block_size == P, (P, block_size)
    # [B, S, Hkv, rep, hd] -> [B, Hkv, S, rep, hd] -> [B, Hkv, S*rep, hd]
    qk = q.reshape(B, S, Hkv, rep, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, S * rep, hd)
    kp = k_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    vp = v_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                          (B,))
    out = paged_attention_kernel(qk, kp, vp,
                                 jnp.asarray(block_table, jnp.int32), cl,
                                 block_size=block_size, softcap=softcap,
                                 q_len=S, interpret=interpret)
    return out.reshape(B, Hkv, S, rep, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, hd)
