"""Jitted wrapper used by models/attention.py (layout adaptation)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import \
    paged_attention_kernel

# target rows (q positions x rep) per kernel q tile; the auto choice
# keeps small windows (decode, verify k<=4) on a single tile so the
# scratch/flush schedule matches the pre-q-tiling kernel exactly
_Q_TILE_ROWS = 512


def largest_block_divisor(n: int, cap: int = 128) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1 always exists).

    Used to view a slab scratch cache [B, S_max] as a pool of
    ``S_max // bs`` contiguous blocks per row so the same paged kernel
    can serve prefill continuation (see models/attention.py).
    """
    for bs in range(min(cap, n), 0, -1):
        if n % bs == 0:
            return bs
    return 1


def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, cache_len: jnp.ndarray, *,
                    block_size: int, softcap: float = 0.0,
                    q_tile: Optional[int] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Model-layout entry: q [B, S, H, hd] with S >= 1 query positions
    (S = 1 is plain decode; S = k + 1 is a speculative-verify window;
    S = chunk is a prefill chunk — always causal within the window);
    k_pool/v_pool [1, P, Hkv, hd] *physical* pools with
    P = num_blocks * block_size (the serve engine's paged cache leaves);
    block_table [B, n_blocks] int32; cache_len scalar or per-row [B] —
    the total valid length INCLUDING the S window positions (query i
    sits at absolute position ``cache_len - S + i``) -> [B, S, H, hd].

    The pool's KV axis is viewed as [num_blocks, block_size] (pure
    reshape, no copy) and q as [B, Hkv, S * rep, hd] (query i, q head
    h = g * rep + r at row i * rep + r — the ``_repeat_kv`` head order per
    query), so the kernel can index whole physical blocks and handle GQA
    and the query window in its index maps and mask.

    ``q_tile`` (queries per kernel q tile) defaults to all of S when
    S * rep fits one ~512-row tile, else ~512 // rep; S is zero-padded at
    the deep end up to a tile multiple (ragged last tile) and the padded
    outputs are dropped here.
    """
    B, S, H, hd = q.shape
    P, Hkv = k_pool.shape[1], k_pool.shape[2]
    rep = H // Hkv
    num_blocks = P // block_size
    assert num_blocks * block_size == P, (P, block_size)
    if q_tile is None:
        q_tile = S if S * rep <= _Q_TILE_ROWS else max(1, _Q_TILE_ROWS // rep)
    q_tile = min(q_tile, S)
    q_pad = -(-S // q_tile) * q_tile
    # [B, S, Hkv, rep, hd] -> [B, Hkv, S, rep, hd] -> [B, Hkv, S*rep, hd]
    qk = q.reshape(B, S, Hkv, rep, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, Hkv, S * rep, hd)
    if q_pad > S:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, (q_pad - S) * rep), (0, 0)))
    kp = k_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    vp = v_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                          (B,))
    out = paged_attention_kernel(qk, kp, vp,
                                 jnp.asarray(block_table, jnp.int32), cl,
                                 block_size=block_size, softcap=softcap,
                                 q_len=S, q_tile=q_tile, rep=rep,
                                 interpret=interpret)
    return out[:, :, :S * rep].reshape(B, Hkv, S, rep, hd) \
        .transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd)
