"""Jitted wrapper used by models/attention.py (layout adaptation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import \
    paged_attention_kernel


def paged_attention(q: jnp.ndarray, k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                    block_table: jnp.ndarray, cache_len: jnp.ndarray, *,
                    block_size: int, softcap: float = 0.0,
                    interpret: bool = False) -> jnp.ndarray:
    """Model-layout entry: q [B, 1, H, hd]; k_pool/v_pool [1, P, Hkv, hd]
    *physical* pools with P = num_blocks * block_size (the serve engine's
    paged cache leaves); block_table [B, n_blocks] int32; cache_len scalar
    or per-row [B] -> [B, 1, H, hd].

    The pool's KV axis is viewed as [num_blocks, block_size] (pure
    reshape, no copy) and q as [B, Hkv, rep, hd] (q head h = g * rep + r,
    the ``_repeat_kv`` head order), so the kernel can index whole physical
    blocks and handle GQA in its index maps.
    """
    B, _, H, hd = q.shape
    P, Hkv = k_pool.shape[1], k_pool.shape[2]
    rep = H // Hkv
    num_blocks = P // block_size
    assert num_blocks * block_size == P, (P, block_size)
    qk = q[:, 0].reshape(B, Hkv, rep, hd)
    kp = k_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    vp = v_pool[0].reshape(num_blocks, block_size, Hkv, hd)
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                          (B,))
    out = paged_attention_kernel(qk, kp, vp,
                                 jnp.asarray(block_table, jnp.int32), cl,
                                 block_size=block_size, softcap=softcap,
                                 interpret=interpret)
    return out.reshape(B, 1, H, hd)
