"""Pallas TPU kernel: paged decode attention (fused block-table gather),
with multi-query tiles for speculative verify.

One row's query token(s) attend over that row's KV block chain *through
the block table inside the kernel*: grid (batch, kv_heads, kv block
tiles); the [B, n_blocks] block table and the [B] valid lengths ride in
as scalar-prefetch operands, so tile j of row b fetches physical block
``block_table[b, j]`` straight out of the pool in the K/V BlockSpec
index_map — the [B, L_max] logical index gather and the per-q-head K/V
repeat of the XLA reference (``models.attention.paged_decode_attention``)
never materialize. Running (m, l, acc) live in VMEM scratch across the
tile dimension (online softmax); tiles at or past a row's valid length
are skipped with @pl.when (no MXU work — and their pipeline fetch still
lands on a real block id, because unallocated table entries point at the
null block, so there is no out-of-bounds traffic either). GQA is handled
in the q/out index maps like the flash kernel: q is viewed
[B, Hkv, q_len * rep, hd] and each (b, g) program computes all q
positions x ``rep`` q heads of kv head g, so K/V are never repeated.

Multi-query tiles (``q_len > 1``, the speculative-verify window): the
q block simply grows to ``q_len * rep`` rows walking the SAME block
chain — query position i (absolute position ``length - q_len + i``)
is masked causally within the window, ``kv_pos <= length - q_len + i``.
``q_len == 1`` takes a static branch with the original single-query
mask (``kv_pos < length``) so the decode path stays bit-identical to
the pre-multi-query kernel.

VMEM budget per step (block_size=16, hd=128, rep=8, q_len=4, bf16):
q/out 16 kB + k/v 2x4 kB + acc/l/m f32 ~17 kB — far under 16 MB, so the
pipeline double-buffers block fetches freely; per-step compute is one
[q_len * rep, hd] x [hd, bs] and one [q_len * rep, bs] x [bs, hd] MXU
pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params as _tpu_compiler_params

_NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, block_size: int, n_blocks: int, softcap: float,
            scale: float, q_len: int, rep: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    # ragged lengths / null-block tail: tiles with no valid position are
    # skipped entirely (no MXU work, no softmax update).  The deepest
    # query attends positions < length, so the bound is q_len-invariant.
    @pl.when(j * block_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [q_len*rep, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)             # [bs, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kv_pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        if q_len == 1:
            # single-query decode: the original mask, kept on its own
            # static branch so this path stays bit-identical
            s = jnp.where(kv_pos < length, s, _NEG_INF)
        else:
            # speculative window: row r holds query i = r // rep at
            # absolute position length - q_len + i; causal within the
            # window (reduces to the branch above at q_len == 1)
            row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            q_pos = length - q_len + row // rep
            s = jnp.where(kv_pos <= q_pos, s, _NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0, :, 0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           cache_len: jnp.ndarray, *, block_size: int,
                           softcap: float = 0.0, q_len: int = 1,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hkv, q_len * rep, hd] (query i, q-head r of kv head g at row
    ``i * rep + r``); k_pool/v_pool: [num_blocks, block_size, Hkv, hd];
    block_table: [B, n_blocks] int32 (entries past a row's chain must
    point at a valid physical block — the pool's null-block convention);
    cache_len: [B] int32 valid lengths INCLUDING the q_len window (query i
    sits at absolute position ``cache_len - q_len + i``)
    -> [B, Hkv, q_len * rep, hd]."""
    B, Hkv, QR, hd = q.shape
    assert QR % q_len == 0, (QR, q_len)
    rep = QR // q_len
    n_blocks = block_table.shape[1]
    assert k_pool.shape[1] == block_size and k_pool.shape[2] == Hkv
    scale = hd ** -0.5
    grid = (B, Hkv, n_blocks)

    def q_index(b, g, j, bt, cl):
        return (b, g, 0, 0)

    def kv_index(b, g, j, bt, cl):
        return (bt[b, j], 0, g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, QR, hd), q_index),
            pl.BlockSpec((1, block_size, 1, hd), kv_index),
            pl.BlockSpec((1, block_size, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, QR, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((QR, 1), jnp.float32),
            pltpu.VMEM((QR, 1), jnp.float32),
            pltpu.VMEM((QR, hd), jnp.float32),
        ])
    fn = pl.pallas_call(
        functools.partial(_kernel, block_size=block_size, n_blocks=n_blocks,
                          softcap=softcap, scale=scale, q_len=q_len,
                          rep=rep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, QR, hd), q.dtype),
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret)
    return fn(block_table.astype(jnp.int32), cache_len.astype(jnp.int32),
              q, k_pool, v_pool)
