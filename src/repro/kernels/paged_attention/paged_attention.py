"""Pallas TPU kernel: paged attention (fused block-table gather) with
q-tiling — one kernel for decode, speculative verify, and chunked /
prefix-tail prefill.

One row's query token(s) attend over that row's KV block chain *through
the block table inside the kernel*: grid (batch, kv_heads, q tiles, kv
block tiles); the [B, n_blocks] block table and the [B] valid lengths
ride in as scalar-prefetch operands, so kv tile j of row b fetches
physical block ``block_table[b, j]`` straight out of the pool in the K/V
BlockSpec index_map — the [B, L_max] logical index gather and the
per-q-head K/V repeat of the XLA reference
(``models.attention.paged_decode_attention``) never materialize.

Q-tiling (flash-style, both axes): queries are split into tiles of
``q_blk`` positions x ``rep`` q-heads-per-kv-head rows; the kv-tile
dimension is innermost, so each (b, g, t) program walks the whole block
chain with running (m, l, acc) in VMEM scratch (online softmax), reset
at kv tile 0 and flushed at the last kv tile.  Causal pruning is
two-sided: kv tiles past a row's valid ``length`` are dead for every q
tile, and kv tiles past q tile t's deepest query (absolute position
``length - q_len + min((t+1)*q_blk, q_len) - 1``) are dead for that q
tile — both are skipped with @pl.when (no MXU work), and the K/V
index_map *clamps* pruned tiles to the last live block so Pallas's
same-block revisiting elides their pipeline copies (no redundant HBM
traffic on the causal tail).

Ragged last tiles: ``q_len`` need not be a multiple of ``q_blk`` — the
wrapper zero-pads queries at the deep end and rows past ``q_len`` are
masked with ``kv_pos < length`` (their causal bound lies past the valid
range), producing finite garbage the wrapper drops.

Masks are parameterized by absolute position: query i sits at
``length - q_len + i`` where ``length`` (= cache_len) INCLUDES the
window, so a prefix-tail prefill that restarts mid-sequence at offset
``q_offset`` passes ``cache_len = q_offset + q_len`` and masks exactly
like ``chunked_attention(..., q_offset=q_offset)``.  ``q_len == 1``
takes a static branch with the original single-query mask
(``kv_pos < length``) so the decode path stays bit-identical to the
pre-q-tiling kernel.

VMEM budget per step (block_size=16, hd=128, rep=8, q_blk=64, bf16):
q/out 2x128 kB + k/v 2x4 kB + acc/l/m f32 ~260 kB — far under 16 MB, so
the pipeline double-buffers block fetches freely; per-step compute is
one [q_blk * rep, hd] x [hd, bs] and one [q_blk * rep, bs] x [bs, hd]
MXU pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params as _tpu_compiler_params

_NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, block_size: int, n_blocks: int, softcap: float,
            scale: float, q_len: int, q_blk: int, rep: int):
    b = pl.program_id(0)
    t = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    # deepest *real* query of q tile t (padded rows lie past q_len and
    # never extend the bound); at q_len == q_blk == 1 this reduces to
    # the original decode bound j * block_size < length
    hi = length - q_len + jnp.minimum((t + 1) * q_blk, q_len) - 1

    # ragged lengths / null-block tail / causal tail: kv tiles with no
    # position visible to this q tile are skipped entirely (no MXU work,
    # no softmax update; their pipeline fetch is elided by the clamped
    # index_map below).
    @pl.when(j * block_size <= hi)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [q_blk*rep, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)             # [bs, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kv_pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        if q_len == 1:
            # single-query decode: the original mask, kept on its own
            # static branch so this path stays bit-identical
            s = jnp.where(kv_pos < length, s, _NEG_INF)
        else:
            # q tile t, row r holds query i = t * q_blk + r // rep at
            # absolute position length - q_len + i; causal within the
            # window.  Padded rows (i >= q_len) have a causal bound past
            # the valid range, so they additionally need kv_pos < length
            # to stay off null-block garbage (a no-op for real rows,
            # whose q_pos < length already).
            row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            q_pos = length - q_len + t * q_blk + row // rep
            s = jnp.where((kv_pos <= q_pos) & (kv_pos < length), s,
                          _NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0, :, 0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           cache_len: jnp.ndarray, *, block_size: int,
                           softcap: float = 0.0, q_len: int = 1,
                           q_tile: Optional[int] = None,
                           rep: Optional[int] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hkv, q_pad * rep, hd] (query i, q-head r of kv head g at row
    ``i * rep + r``), where ``q_pad = ceil(q_len / q_tile) * q_tile`` —
    rows past ``q_len * rep`` are zero padding whose outputs the caller
    drops; k_pool/v_pool: [num_blocks, block_size, Hkv, hd]; block_table:
    [B, n_blocks] int32 (entries past a row's chain must point at a valid
    physical block — the pool's null-block convention); cache_len: [B]
    int32 valid lengths INCLUDING the q_len window (query i sits at
    absolute position ``cache_len - q_len + i``)
    -> [B, Hkv, q_pad * rep, hd].

    ``q_tile=None`` means one tile covering all q_len queries (the
    pre-q-tiling layout: no padding, QR == q_len * rep); ``rep`` is then
    derived from the shapes.
    """
    B, Hkv, QR, hd = q.shape
    if q_tile is None:
        q_tile = q_len
    if rep is None:
        assert QR % q_len == 0, (QR, q_len)
        rep = QR // q_len
    tile_rows = q_tile * rep
    assert QR % tile_rows == 0, (QR, q_tile, rep)
    n_q_tiles = QR // tile_rows
    assert n_q_tiles * q_tile >= q_len, (n_q_tiles, q_tile, q_len)
    n_blocks = block_table.shape[1]
    assert k_pool.shape[1] == block_size and k_pool.shape[2] == Hkv
    scale = hd ** -0.5
    grid = (B, Hkv, n_q_tiles, n_blocks)

    def q_index(b, g, t, j, bt, cl):
        return (b, g, t, 0)

    def kv_index(b, g, t, j, bt, cl):
        # clamp dead kv tiles (past the row's length or past q tile t's
        # causal bound) to the last live tile: consecutive grid steps
        # then map to the same physical block and Pallas elides the copy
        hi = cl[b] - q_len + jnp.minimum((t + 1) * q_tile, q_len) - 1
        jj = jnp.clip(jnp.minimum(j, hi // block_size), 0, n_blocks - 1)
        return (bt[b, jj], 0, g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tile_rows, hd), q_index),
            pl.BlockSpec((1, block_size, 1, hd), kv_index),
            pl.BlockSpec((1, block_size, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_rows, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((tile_rows, 1), jnp.float32),
            pltpu.VMEM((tile_rows, 1), jnp.float32),
            pltpu.VMEM((tile_rows, hd), jnp.float32),
        ])
    fn = pl.pallas_call(
        functools.partial(_kernel, block_size=block_size, n_blocks=n_blocks,
                          softcap=softcap, scale=scale, q_len=q_len,
                          q_blk=q_tile, rep=rep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, QR, hd), q.dtype),
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret)
    return fn(block_table.astype(jnp.int32), cache_len.astype(jnp.int32),
              q, k_pool, v_pool)
