"""Pure-jnp oracle for the paged decode-attention kernel.

Deliberately does what the kernel avoids: gathers each row's full
[L_max, Hkv, hd] logical K/V view through its block-table row, repeats KV
heads to the q-head count, and runs a masked softmax over the whole
logical range — the reference semantics the fused kernel must match
bit-for-tolerance (it mirrors ``models.attention.paged_decode_attention``,
which the parity tests also compare against).  Multi-query windows
(S > 1, speculative verify) mask causally within the window: query i
attends kv positions ``<= cache_len - S + i``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def paged_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_table: jnp.ndarray,
                        cache_len: jnp.ndarray, *, block_size: int,
                        softcap: float = 0.0) -> jnp.ndarray:
    """Same layout contract as ``ops.paged_attention``: q [B, S, H, hd];
    k_pool/v_pool [1, P, Hkv, hd] physical pools; block_table
    [B, n_blocks]; cache_len scalar or [B], the total valid length
    including the S window positions -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    Hkv = k_pool.shape[2]
    rep = H // Hkv
    n_blocks = block_table.shape[1]
    log = jnp.arange(n_blocks * block_size)
    phys = block_table[:, log // block_size] * block_size + log % block_size
    k = k_pool[0, phys]                                 # [B, L_max, Hkv, hd]
    v = v_pool[0, phys]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.astype(jnp.float32) * hd ** -0.5             # [B, S, H, hd]
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    cl = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,))
    q_pos = cl[:, None] - S + jnp.arange(S)[None]       # [B, S]
    mask = log[None, None, :] <= q_pos[:, :, None]      # [B, S, L_max]
    s = jnp.where(mask[:, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
