# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
# Shipped triads (see README.md): flash_attention (prefill/train),
# paged_attention (serve decode through the paged KV pool), moe_gmm
# (grouped expert FFN).
