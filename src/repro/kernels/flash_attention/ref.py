"""Pure-jnp oracle for the flash attention kernel (naive softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True) -> jnp.ndarray:
    """q: [B, H, Sq, hd]; k/v: [B, Hkv, Sk, hd]."""
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = H // Hkv
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(B, Hkv, rep, Sq, hd)
    s = jnp.einsum("bgrqh,bgkh->bgrqk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bgkh->bgrqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
