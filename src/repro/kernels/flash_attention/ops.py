"""Jitted wrapper used by models/attention.py (layout adaptation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, interpret: bool = False) -> jnp.ndarray:
    """q/k/v in model layout [B, S, H, hd] -> [B, S, H, hd]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_kernel(qt, kt, vt, causal=causal, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
