"""Pallas TPU kernel: causal flash attention (online softmax).

Grid (batch*q_heads, q_tiles, kv_tiles); running (m, l, acc) live in VMEM
scratch across the kv dimension; fully-masked kv tiles above the causal
diagonal are skipped with @pl.when (no MXU work, no VMEM traffic beyond the
pipelined block fetch). GQA is handled in the BlockSpec index_map
(q head h reads kv head h // rep) so K/V are never materialized per-q-head.

VMEM budget per step (block_q=block_k=512, hd=128, bf16):
q 128 kB + k/v 256 kB + acc/l/m f32 ~290 kB — far under 16 MB, leaving the
pipeline room to double-buffer K/V blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params as _tpu_compiler_params

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_kv: int,
            causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks entirely above the diagonal
    run = (not causal) or (kj * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, Sq, hd]; k/v: [B, Hkv, Sk, hd] -> [B, H, Sq, hd]."""
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_kv = Sq // block_q, Sk // block_k
    grid = (B * H, n_q, n_kv)
    scale = hd ** -0.5

    qr = q.reshape(B * H, Sq, hd)
    kr = k.reshape(B * Hkv, Sk, hd)
    vr = v.reshape(B * Hkv, Sk, hd)

    def kv_index(bh, qi, kj):
        b, h = bh // H, bh % H
        return (b * Hkv + h // rep, kj, 0)

    fn = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, n_kv=n_kv, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret)
    return fn(qr, kr, vr).reshape(B, H, Sq, hd)
