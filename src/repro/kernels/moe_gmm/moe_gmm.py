"""Pallas TPU kernel: fused grouped expert FFN (the MoE compute hot-spot).

Computes, per block-aligned group g (one expert slot) over the dispatch
buffer:  out = act(x @ w_in[g]) [* silu(x @ w_gate[g])] @ w_out[g]

Design (TPU-native adaptation of the paper's async expert fetching, one level
down the memory hierarchy — DESIGN.md §2):

  * grid = (m_tiles, f_tiles): every m-tile belongs to EXACTLY one group
    because the dispatch buffer aligns group starts to ``block_m``
    (dispatch.py); the tile->group map rides in as a *scalar-prefetch*
    operand driving the weight BlockSpec index_map, so the Pallas pipeline
    streams each tile's expert-weight blocks HBM->VMEM with double buffering
    while the previous tile computes — the kernel-level analogue of
    "fetch the next expert while the current one runs" (paper §4.3).
  * the hidden dimension f is tiled by ``block_f`` and accumulated in an
    f32 VMEM scratch: elementwise activations commute with f-blocking, so
    the [m, f] intermediate is NEVER materialized in HBM (pure-XLA MoE
    implementations write it out — this is the kernel's memory-roofline win).
  * MXU alignment: block_m = 128, block_f a multiple of 128, d assumed
    128-aligned (model configs pad).

Zero-padding rows inside a group produce exact zeros (act(0)=0 for
gelu/silu/relu and 0 * w = 0), so no masking is needed for correctness.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params as _tpu_compiler_params


def _apply_act(act: str, h):
    if act == "gelu":
        return jax.nn.gelu(h)
    if act == "relu":
        return jax.nn.relu(h)
    if act == "silu":
        return jax.nn.silu(h)
    raise ValueError(act)


def _kernel_gated(tile_group_ref, x_ref, w_gate_ref, w_in_ref, w_out_ref,
                  o_ref, acc_ref, *, act: str, n_f_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    h_up = jnp.dot(x, w_in_ref[0], preferred_element_type=jnp.float32)
    h_gate = jnp.dot(x, w_gate_ref[0], preferred_element_type=jnp.float32)
    h = _apply_act("silu", h_gate) * h_up
    acc_ref[...] += jnp.dot(h.astype(x.dtype), w_out_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_f_tiles - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_plain(tile_group_ref, x_ref, w_in_ref, w_out_ref,
                  o_ref, acc_ref, *, act: str, n_f_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    h = jnp.dot(x, w_in_ref[0], preferred_element_type=jnp.float32)
    h = _apply_act(act, h)
    acc_ref[...] += jnp.dot(h.astype(x.dtype), w_out_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_f_tiles - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
            tile_group: jnp.ndarray, *,
            w_gate: Optional[jnp.ndarray] = None, act: str = "gelu",
            block_m: int = 128, block_f: int = 512,
            interpret: bool = False) -> jnp.ndarray:
    """x [M, d] (M % block_m == 0); w_in/w_gate [G, d, f]; w_out [G, f, d];
    tile_group [M // block_m] int32 in [0, G)."""
    M, d = x.shape
    G, _, f = w_in.shape
    assert M % block_m == 0, (M, block_m)
    n_m = M // block_m
    block_f = min(block_f, f)
    assert f % block_f == 0, (f, block_f)
    n_f = f // block_f

    grid = (n_m, n_f)
    x_spec = pl.BlockSpec((block_m, d), lambda i, j, tg: (i, 0))
    w_in_spec = pl.BlockSpec((1, d, block_f), lambda i, j, tg: (tg[i], 0, j))
    w_out_spec = pl.BlockSpec((1, block_f, d), lambda i, j, tg: (tg[i], j, 0))
    o_spec = pl.BlockSpec((block_m, d), lambda i, j, tg: (i, 0))
    scratch = [pltpu.VMEM((block_m, d), jnp.float32)]

    if w_gate is not None:
        w_gate_spec = pl.BlockSpec((1, d, block_f),
                                   lambda i, j, tg: (tg[i], 0, j))
        kernel = functools.partial(_kernel_gated, act=act, n_f_tiles=n_f)
        in_specs = [x_spec, w_gate_spec, w_in_spec, w_out_spec]
        operands = (x, w_gate, w_in, w_out)
    else:
        kernel = functools.partial(_kernel_plain, act=act, n_f_tiles=n_f)
        in_specs = [x_spec, w_in_spec, w_out_spec]
        operands = (x, w_in, w_out)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=o_spec, scratch_shapes=scratch)
    fn = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, d), x.dtype),
        compiler_params=_tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret)
    return fn(tile_group.astype(jnp.int32), *operands)
