"""Jitted wrapper: grouped-FFN entry point used by core/grouped_ffn.py."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.moe_gmm.moe_gmm import moe_gmm


def tile_group_map(group_sizes_padded: jnp.ndarray, n_tiles: int,
                   block_m: int) -> jnp.ndarray:
    """tile index -> group id from block-aligned group extents.

    Tiles beyond the last group map to the final group (their rows are
    zeros, producing exact zeros)."""
    offsets = jnp.cumsum(group_sizes_padded)         # end offset per group
    starts = jnp.arange(n_tiles, dtype=jnp.int32) * block_m
    tg = jnp.searchsorted(offsets, starts, side="right").astype(jnp.int32)
    return jnp.minimum(tg, group_sizes_padded.shape[0] - 1)


def fused_expert_ffn(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                     group_sizes_padded: jnp.ndarray, *,
                     w_gate: Optional[jnp.ndarray] = None, act: str = "gelu",
                     block_m: int = 128, block_f: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    M = x.shape[0]
    n_tiles = M // block_m
    tg = tile_group_map(group_sizes_padded, n_tiles, block_m)
    return moe_gmm(x, w_in, w_out, tg, w_gate=w_gate, act=act,
                   block_m=block_m, block_f=block_f, interpret=interpret)
