"""Pure-jnp oracle for the fused grouped expert FFN kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _act(name: str, h):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "silu": jax.nn.silu}[name](h)


def moe_gmm_ref(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray,
                tile_group: jnp.ndarray, *,
                w_gate: Optional[jnp.ndarray] = None, act: str = "gelu",
                block_m: int = 128) -> jnp.ndarray:
    """Per-row expert FFN using the tile->group map (exact, O(M*G) masked)."""
    M, d = x.shape
    G = w_in.shape[0]
    row_group = jnp.repeat(tile_group, block_m)[:M]
    out = jnp.zeros((M, d), jnp.float32)
    for g in range(G):
        h = x.astype(jnp.float32) @ w_in[g].astype(jnp.float32)
        if w_gate is not None:
            h = _act("silu", x.astype(jnp.float32)
                     @ w_gate[g].astype(jnp.float32)) * h
        else:
            h = _act(act, h)
        y = h.astype(x.dtype).astype(jnp.float32) @ w_out[g].astype(jnp.float32)
        out = jnp.where((row_group == g)[:, None], y, out)
    return out.astype(x.dtype)
