import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the 16x16
single-pod mesh AND the 2x16x16 multi-pod mesh must compile for every
assigned architecture x input shape, using ShapeDtypeStruct stand-ins (no
allocation). Prints memory_analysis() (fits) and cost_analysis() (FLOPs /
bytes for the roofline), extracts per-collective byte counts from the
compiled HLO, and caches everything to results/dryrun/<cell>.json so the
matrix is resumable.

Usage:
  python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, SHAPE_BY_NAME, SHAPES, get_config,
                           iter_cells)
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis
from repro.launch import shardings as SH
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step, optimizer_shapes
from repro.models.model import MeshShape, build_model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Convention (documented in EXPERIMENTS.md): bytes-on-the-wire per chip is
    approximated by the op's result bytes, x2 for all-reduce (ring RS+AG).
    """
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+(\w[\w\-]*)\(",
                     stripped)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                kind = c
                break
        if kind is None or op.endswith("-done"):
            continue
        nbytes = _shape_bytes(m.group(1))
        factor = 2.0 if kind == "all-reduce" else 1.0
        per_kind[kind] += nbytes * factor
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"per_kind": per_kind, "counts": counts, "total": total}


def parallel_config(cfg: ModelConfig, shape: ShapeConfig) -> ParallelConfig:
    return ParallelConfig(
        fsdp=(shape.kind == "train"),
        remat="full" if shape.kind == "train" else "none",
        shard_kv_seq=(shape.name == "long_500k"),
        microbatch=4 if shape.kind == "train" else 0,
        # 512-row MoE tiles: expert-weight HBM traffic scales ~1/block_m
        # (EXPERIMENTS.md §Perf iteration 4); decode keeps 128 (model.py)
        moe_block_m=512 if shape.kind != "decode" else 128,
        use_pallas=False,   # CPU dry-run lowers the XLA reference path
    )


def _sds_with(shapes: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    pcfg = parallel_config(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_shape = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    n_chips = mesh.devices.size

    model = build_model(cfg, pcfg, batch=shape.global_batch,
                        seq_len=shape.seq_len, mesh_shape=mesh_shape,
                        mesh=mesh)

    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = SH.param_shardings(param_shapes, cfg, pcfg, mesh)
    params_in = _sds_with(param_shapes, p_shard)
    batch_shapes = model.input_specs(shape.kind)
    batch_in = _sds_with(batch_shapes, SH.batch_shardings(
        batch_shapes, global_batch=shape.global_batch, mesh=mesh))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt_shapes = optimizer_shapes(param_shapes)
            o_shard = SH.param_shardings(
                jax.eval_shape(lambda p: p, opt_shapes.m), cfg, pcfg, mesh)
            opt_in = jax.tree_util.tree_map(lambda x: x, opt_shapes)
            opt_in = type(opt_shapes)(
                jax.ShapeDtypeStruct((), jnp.int32),
                _sds_with(opt_shapes.m, o_shard),
                _sds_with(opt_shapes.v, o_shard))
            step = make_train_step(model)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_in, opt_in, batch_in)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, s_max=shape.seq_len + 64)
            lowered = jax.jit(step).lower(params_in, batch_in)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_shard = SH.cache_shardings(
                cache_shapes, cfg, global_batch=shape.global_batch, mesh=mesh,
                shard_kv_seq=pcfg.shard_kv_seq)
            caches_in = _sds_with(cache_shapes, c_shard)
            token_in = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=jax.tree.leaves(SH.batch_shardings(
                    {"t": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32)},
                    global_batch=shape.global_batch, mesh=mesh))[0])
            pos_in = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_decode_step(model)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_in, token_in, caches_in, pos_in)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    corrected = hlo_analysis.analyze(hlo)

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        # raw XLA cost analysis (per device, while-bodies counted ONCE)
        "flops_raw": float(cost.get("flops", 0.0)),
        "bytes_raw": float(cost.get("bytes accessed", 0.0)),
        # trip-count-corrected (per device) — see launch/hlo_analysis.py
        "flops": corrected["flops"],
        "bytes_accessed": corrected["bytes_accessed"],
        "collectives": {
            "total": corrected["collective_bytes"],
            "per_kind": corrected["collectives_per_kind"],
            "counts": corrected["collective_counts"],
            "uncorrected": coll,
        },
        "model_flops": model_flops_estimate(cfg, shape),
        "param_count": param_count(param_shapes),
        "hlo_ops": len(hlo.splitlines()),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'}-pod ({n_chips} chips)]")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {result['memory']}")
        print(f"  per-device corrected: flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e} "
              f"coll={result['collectives']['total']:.3e}B")
        print(f"  (xla raw, scan bodies once: flops={result['flops_raw']:.3e})")
    return result


def param_count(param_shapes: Any) -> int:
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(param_shapes)))


def model_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS (whole step, all chips): 6*N*D train (dense),
    6*N_active*D (MoE); 2*N(_active)*D for forward-only steps."""
    n_total, n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens


def _active_params(cfg: ModelConfig):
    """(total, activated-per-token) parameter counts from the config."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    n_mats = 3 if cfg.act in ("swiglu", "gelu") else 2
    dense_ffn = n_mats * d * cfg.d_ff
    embed = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    ssm = 0
    if cfg.ssm is not None:
        from repro.models.mamba2 import mamba_dims
        dm = mamba_dims(cfg)
        ssm = 2 * d * dm.d_inner + dm.d_inner * d \
            + 2 * d * dm.state + d * dm.n_heads
    if cfg.family == "ssm":
        total = L * ssm + embed
        return total, total
    if cfg.family == "hybrid":
        shared = attn + dense_ffn
        total = L * ssm + shared + embed
        active = total  # shared block applied every group
        return total, active
    if cfg.is_moe:
        m = cfg.moe
        n_moe = (L - m.first_dense_layers) // m.moe_layer_period
        n_dense_layers = L - n_moe
        expert = n_mats * d * m.d_ff_expert
        shared_e = m.num_shared_experts * expert
        router = d * m.num_experts
        total = (L * attn + n_dense_layers * dense_ffn
                 + n_moe * (m.num_experts * expert + shared_e + router) + embed)
        active = (L * attn + n_dense_layers * dense_ffn
                  + n_moe * (m.num_experts_per_tok * expert + shared_e + router)
                  + embed)
        return total, active
    enc = cfg.encoder_layers * (attn + dense_ffn) if cfg.is_encoder_decoder else 0
    cross = L * 4 * d * cfg.num_heads * hd if cfg.is_encoder_decoder else 0
    total = L * (attn + dense_ffn) + enc + cross + embed
    return total, total


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = "multi" if multi_pod else "single"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{tag}.json")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             force: bool = False) -> Dict[str, Any]:
    path = cell_path(arch, shape_name, multi_pod)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    try:
        result = lower_cell(arch, shape_name, multi_pod=multi_pod)
    except Exception as e:  # record failures for triage, then re-raise
        result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()}
        with open(path + ".failed", "w") as f:
            json.dump(result, f, indent=2)
        raise
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        ok, failed = 0, []
        for mp in meshes:
            for arch, shape, runnable, why in iter_cells(include_skipped=True):
                if not runnable:
                    print(f"[skip] {arch} x {shape.name}: {why}")
                    continue
                try:
                    run_cell(arch, shape.name, multi_pod=mp, force=args.force)
                    ok += 1
                except Exception as e:
                    print(f"[FAIL] {arch} x {shape.name} x "
                          f"{'multi' if mp else 'single'}: {e}")
                    failed.append((arch, shape.name, mp))
        print(f"\ndry-run matrix: {ok} cells ok, {len(failed)} failed")
        for f in failed:
            print("  FAILED:", f)
        raise SystemExit(1 if failed else 0)

    assert args.arch and args.shape
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             force=args.force)


if __name__ == "__main__":
    main()
