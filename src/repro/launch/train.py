"""Training driver: resumable, fault-tolerant end-to-end loop.

Example (CPU, small model):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --reduced \
      --steps 50 --batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt --resume auto

At production scale the same driver runs under the 16x16 mesh with the
sharding rules from launch/shardings.py; on this container it runs on the
host devices. Fault tolerance: checkpoint every --ckpt-every steps (async),
auto-resume from the latest committed checkpoint, optional injected failure
via REPRO_FAIL_AT_STEP for drills (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.data.pipeline import PrefetchLoader, synthetic_batches
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.model import MeshShape, build_model
from repro.optim.adamw import adamw_init
from repro.runtime.fault_tolerance import FailureInjector


def train_loop(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pcfg = ParallelConfig(remat=args.remat, microbatch=args.microbatch,
                          attn_chunk=min(512, args.seq_len),
                          loss_chunk=min(2048, args.seq_len))
    n_dev = len(jax.devices())
    data = args.data_par or max(1, n_dev // max(args.model_par, 1))
    mesh = make_host_mesh(data=data, model=args.model_par)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, pcfg, batch=args.batch, seq_len=args.seq_len,
                        mesh_shape=ms, mesh=mesh)

    ckpt = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    injector = FailureInjector.from_env()

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        opt = adamw_init(params)
        start = 0
        if ckpt and args.resume == "auto":
            latest = ckpt.latest_step()
            if latest is not None:
                state = ckpt.restore(latest, {"params": params, "opt": opt})
                params, opt = state["params"], state["opt"]
                start = latest
                print(f"[train] resumed from step {latest}")

        step_fn = jax.jit(make_train_step(model, lr=args.lr),
                          donate_argnums=(0, 1))
        loader = PrefetchLoader(synthetic_batches(
            args.dataset, batch=args.batch, seq_len=args.seq_len,
            vocab=cfg.vocab_size, seed=args.seed))

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            injector.check(step)
            batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
            params, opt, loss, diags = step_fn(params, opt, batch)
            losses.append(float(loss))
            if (step + 1) % args.log_every == 0:
                tput = args.log_every * args.batch * args.seq_len \
                    / (time.time() - t0)
                extra = ""
                if "send_drops" in diags:
                    extra = (f" drops={float(diags['send_drops']):.0f}"
                             f" moved={float(diags.get('moved_units', 0)):.0f}")
                print(f"[train] step {step + 1} loss {float(loss):.4f} "
                      f"tok/s {tput:.0f}{extra}")
                t0 = time.time()
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt},
                      blocking=True)
        loader.close()
        if len(losses) >= 10:
            a, b = np.mean(losses[:5]), np.mean(losses[-5:])
            print(f"[train] loss {a:.4f} -> {b:.4f} "
                  f"({'improved' if b < a else 'NOT improved'})")
    return args.steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dataset", default="random",
                    choices=["random", "constant", "zipf"])
    ap.add_argument("--remat", default="none", choices=["none", "full"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--data-par", type=int, default=0)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"])
    train_loop(ap.parse_args())


if __name__ == "__main__":
    main()
