"""Sharding rules: parameter / optimizer / cache PartitionSpecs per arch.

Parallelism mapping (DESIGN.md §3):
  * EP   — MoE expert slot rows over 'model' (required by the shard_map island)
  * TP   — attention heads, dense-FFN hidden, SSM inner channels, vocab over
           'model' (skipped per-leaf when not divisible, e.g. whisper's 20 heads)
  * DP   — batch over ('pod', 'data')
  * FSDP — with ``ParallelConfig.fsdp``, params/opt-state additionally sharded
           over 'data' on a non-'model' dim; XLA all-gathers at use
  * SP   — long-context KV caches: sequence over 'data' when batch can't shard
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _tp(nd: int, *trailing) -> P:
    """PartitionSpec on the trailing dims, leading (stacking) dims replicated."""
    lead = nd - len(trailing)
    return P(*([None] * lead + list(trailing)))


def param_spec(path, leaf, cfg: ModelConfig, *, ep: int, fsdp: bool,
               data: int) -> P:
    name = _path_str(path)
    shape = leaf.shape
    nd = len(shape)

    def div(n, by):
        return by > 0 and n % by == 0

    # ---------- base (TP/EP) rule ----------
    if "embed" in name or "lm_head" in name:
        # vocab sharding pays for big tables (and their gradients); small
        # tables replicate — XLA's sharded-gather lowering (one-hot select +
        # all-reduce, in f32) costs several [B,S,d] buffers per lookup
        big_vocab = shape[-2] >= 100_000
        spec = (_tp(nd, "model", None) if div(shape[-2], ep) and big_vocab
                else _tp(nd, None, None))
    elif "router" in name:
        spec = _tp(nd, None, None)
    elif "/moe/" in name and ("w_in" in name or "w_gate" in name or "w_out" in name):
        if div(shape[-3], ep):                   # EP: expert slot rows over 'model'
            spec = _tp(nd, "model", None, None)
        elif "w_out" in name and div(shape[-2], ep):
            spec = _tp(nd, None, "model", None)  # TP mode: d_ff-sliced
        elif "w_out" not in name and div(shape[-1], ep):
            spec = _tp(nd, None, None, "model")
        else:
            spec = _tp(nd, None, None, None)
    elif name.endswith("wq") or name.endswith("wk") or name.endswith("wv"):
        spec = (_tp(nd, None, "model", None) if div(shape[-2], ep)
                else _tp(nd, None, None, None))
    elif name.endswith("wo"):
        spec = (_tp(nd, "model", None, None) if div(shape[-3], ep)
                else _tp(nd, None, None, None))
    elif "w_in" in name or "w_gate" in name:     # dense MLP column-parallel
        spec = (_tp(nd, None, "model") if div(shape[-1], ep)
                else _tp(nd, None, None))
    elif "w_out" in name:                        # dense MLP row-parallel
        spec = (_tp(nd, "model", None) if div(shape[-2], ep)
                else _tp(nd, None, None))
    elif name.endswith("wz") or name.endswith("wx"):
        spec = (_tp(nd, None, "model") if div(shape[-1], ep)
                else _tp(nd, None, None))
    elif name.endswith("out_proj"):
        spec = (_tp(nd, "model", None) if div(shape[-2], ep)
                else _tp(nd, None, None))
    elif name.endswith("conv_x"):
        spec = (_tp(nd, None, "model") if div(shape[-1], ep)
                else _tp(nd, None, None))
    elif name.endswith("A_log") or name.endswith("/D") or name.endswith("dt_bias"):
        spec = _tp(nd, "model") if div(shape[-1], ep) else _tp(nd, None)
    elif name.endswith("norm_scale"):
        spec = _tp(nd, "model") if div(shape[-1], ep) else _tp(nd, None)
    else:
        spec = P(*([None] * nd))

    # ---------- FSDP overlay: shard one replicated dim over 'data' ----------
    if fsdp and data > 1 and leaf.size >= (1 << 16):
        parts = list(spec) + [None] * (nd - len(spec))
        # NEVER the leading dim of stacked (>=3D) leaves: that's the
        # scan-over-layers stack, and slicing a 'data'-sharded stack forces
        # XLA to all-gather ALL layers' weights inside every scan step
        # (observed 40x AG blowup on mistral-nemo train — EXPERIMENTS.md
        # §Perf iteration 1).
        start = 1 if nd >= 3 else 0
        for i in range(start, nd):
            if parts[i] is None and div(shape[i], data):
                parts[i] = "data"
                break
        spec = P(*parts)
    return spec


def param_shardings(param_shapes: Any, cfg: ModelConfig, pcfg: ParallelConfig,
                    mesh: jax.sharding.Mesh) -> Any:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes.get("model", 1)
    data = sizes.get("data", 1)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, cfg, ep=ep, fsdp=pcfg.fsdp,
                             data=data)),
        param_shapes)


# ----------------------------------------------------------------------
# KV / SSM cache shardings
# ----------------------------------------------------------------------
def cache_spec(path, leaf, cfg: ModelConfig, *, batch_axes, ep: int,
               shard_kv_seq: bool) -> P:
    name = _path_str(path)
    shape = leaf.shape
    nd = len(shape)

    def div(n, by):
        return by > 0 and n % by == 0

    bspec = batch_axes if batch_axes else None
    if name.endswith("/k") or name.endswith("/v"):
        # [(stack dims...), B, S, Hkv, hd]. Prefer head sharding (TP decode);
        # when heads don't divide the axis, shard the SEQUENCE over 'model'
        # instead (flash-decode style — XLA partitions the softmax reduction)
        if div(shape[-2], ep):
            head_s, seq_s = "model", ("data" if shard_kv_seq else None)
        elif div(shape[-3], ep):
            head_s, seq_s = None, "model"
        else:
            head_s, seq_s = None, None
        if bspec is not None:
            return _tp(nd, bspec, seq_s if seq_s == "model" else None,
                       head_s, None)
        if seq_s != "model" and shard_kv_seq and div(shape[-3], 1):
            seq_s = "data"
        return _tp(nd, None, seq_s, head_s, None)
    if "ssm" in name:
        # [(stack), B, H, P, N]
        head_s = "model" if div(shape[-3], ep) else None
        return _tp(nd, bspec, head_s, None, None)
    if "conv_x" in name:
        ch_s = "model" if div(shape[-1], ep) else None
        return _tp(nd, bspec, None, ch_s)
    if "conv" in name:
        return _tp(nd, bspec, None, None)
    return P(*([None] * nd))


def cache_shardings(cache_shapes: Any, cfg: ModelConfig, *, global_batch: int,
                    mesh: jax.sharding.Mesh, shard_kv_seq: bool = False) -> Any:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes.get("model", 1)
    cand = [a for a in ("pod", "data") if a in sizes]
    batch_axes: tuple = ()
    prod = 1
    for a in cand:
        if global_batch % (prod * sizes[a]) == 0:
            batch_axes += (a,)
            prod *= sizes[a]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, cfg, batch_axes=batch_axes, ep=ep,
                             shard_kv_seq=shard_kv_seq and not batch_axes)),
        cache_shapes)


def batch_shardings(batch_shapes: Any, *, global_batch: int,
                    mesh: jax.sharding.Mesh) -> Any:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cand = [a for a in ("pod", "data") if a in sizes]
    batch_axes: tuple = ()
    prod = 1
    for a in cand:
        if global_batch % (prod * sizes[a]) == 0:
            batch_axes += (a,)
            prod *= sizes[a]
    bspec = batch_axes if batch_axes else None
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, P(*([bspec] + [None] * (len(leaf.shape) - 1)))),
        batch_shapes)


def replicated(tree: Any, mesh: jax.sharding.Mesh) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree)
