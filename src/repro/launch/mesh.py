"""Production mesh construction (assignment-mandated shape).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax init.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the jax version supports
    them (``AxisType`` landed after 0.4.37; older versions default to Auto
    semantics anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) host devices exist (tests/benches)."""
    return make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: jax.sharding.Mesh):
    return tuple(zip(mesh.axis_names, mesh.devices.shape))
