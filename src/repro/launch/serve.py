"""Serving CLI: a thin driver over the ``repro.serve`` continuous-batching
engine (HarMoEny load balancing under request streams).

Example (CPU, small MoE, heavy synthetic skew):
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 64 --gen 16 --skew 0.9 --model-par 4

The old one-shot semantics (one closed batch of ``--batch`` prompts,
lockstep greedy decode) are the default: ``--requests N --rate R`` opens the
loop with N Poisson arrivals at R req/s, admitted into freed decode slots as
earlier requests finish. ``--paged`` swaps the slab KV pool for the paged
block-table pool (block-aware admission, preemption-by-recompute);
``--paged --prefix-sharing`` additionally serves repeated prompt prefixes
out of a copy-on-write block cache (``--shared-prefix-len`` makes the
synthetic prompts actually share one); ``--paged --fused-attention`` swaps
the reference block-table gather for the fused Pallas decode-attention
kernel; ``--temperature``/``--top-k``/``--top-p`` switch greedy decode to
truncated sampling.
Reports per-request TTFT/TPOT percentiles, decode tokens/s, and the
HarMoEny schedule diagnostics (moved units, drops, load balance) — the
paper's §5 metrics.

``--replicas N`` scales out to a fleet of N engine replicas behind a
``FleetRouter`` (virtual replicas: one set of weights on one device
group, one engine + KV pool each, one shared clock) with
``--routing-policy`` load / prefix_affinity / round_robin;
``--disaggregate`` splits the fleet into prefill-role and decode-role
engines connected by the KV handoff path. Fleet runs report aggregate
and per-replica metrics plus routing / handoff diagnostics.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.topology import static_opt_placement
from repro.launch.mesh import make_host_mesh
from repro.models.model import MeshShape, build_model
from repro.serve import (EngineConfig, FleetRouter, ROUTING_POLICIES,
                         ServeEngine, WallClock, engine_config_for,
                         load_trace, poisson_requests)

# ----------------------------------------------------------------------
# EngineConfig-derived flag plumbing.  Every engine knob used to be wired
# three times — argparse declaration, args attribute, engine_config_for
# kwarg — and each new knob repeated the dance.  Now one table row names
# the flag and the ``EngineConfig`` field it sets: the argparse type and
# default come from the dataclass field itself (``add_engine_flags``),
# and the kwargs for ``engine_config_for`` are extracted generically
# (``engine_overrides``).  A ``default`` override in the row marks
# CLI-level "0 = auto" semantics that ``engine_config_for`` resolves
# before ``EngineConfig`` validation sees them.
# ----------------------------------------------------------------------
ENGINE_FLAGS = [
    ("--prefill-chunk", "prefill_chunk",
     dict(default=0, help="prompt tokens per prefill chunk (0 = auto)")),
    ("--paged", "paged",
     dict(help="paged KV pool: block-table attention, block-aware "
               "admission, preemption-by-recompute")),
    ("--kv-block-size", "kv_block_size",
     dict(help="tokens per physical KV block (paged mode)")),
    ("--kv-blocks", "num_kv_blocks",
     dict(help="usable KV blocks (0 = worst case: slab parity)")),
    ("--prefix-sharing", "prefix_sharing",
     dict(help="prefix-sharing KV cache: copy-on-write blocks, radix "
               "prefix index, LRU eviction (needs --paged)")),
    ("--fused-attention", "fused_paged_attention",
     dict(help="fused Pallas attention on every phase: q-tiled paged "
               "attention for prefill / prefix-tail / verify and "
               "block-table decode attention (needs --paged for decode; "
               "interpret mode off-TPU). Strict: raises instead of "
               "silently falling back")),
    ("--fused-moe", "fused_moe_gmm",
     dict(help="grouped-GEMM Pallas expert FFN on prefill/decode/verify "
               "token batches (MoE archs only; interpret mode off-TPU)")),
    ("--speculative-k", "speculative_k",
     dict(help="speculative decoding: verify up to k self-drafted tokens "
               "per decode step in one static [B, k+1] forward (needs "
               "--paged; greedy streams stay token-identical)")),
    ("--speculative-policy", "speculative_policy",
     dict(help="draft proposer (ngram = prompt-lookup self-drafting)")),
    ("--temperature", "temperature",
     dict(help="sampling temperature (0 = greedy)")),
    ("--top-k", "top_k",
     dict(help="truncate sampling to the top-k logits (0 = full)")),
    ("--top-p", "top_p",
     dict(help="nucleus sampling: keep the smallest token set with "
               "cumulative probability >= top-p (1 = off)")),
    ("--replica-slots", "replica_slots",
     dict(help="static hot-expert replica slots per rank (0 = "
               "replication off); swaps never recompile")),
    ("--rebalance-interval", "rebalance_interval",
     dict(help="engine steps between hot-expert weight swaps (0 = "
               "never; needs --replica-slots)")),
    ("--resident-experts", "resident_experts",
     dict(help="tiered expert residency: pod-total HBM working-set "
               "budget in experts (0 = off; must be a multiple of the "
               "EP degree)")),
    ("--prefetch-policy", "prefetch_policy",
     dict(choices=["predictive", "on_demand", "none"],
          help="residency staging policy: predictive = EMA-driven "
               "next-layer prefetch (stalls hidden), on_demand = stage "
               "on first touch, none = frozen initial working set")),
]


def add_engine_flags(ap: argparse.ArgumentParser) -> None:
    """Declare one CLI flag per ``ENGINE_FLAGS`` row, typed and defaulted
    from the ``EngineConfig`` field it maps to (bool fields become
    ``store_true`` switches).  ``dest`` is the field name, so the parsed
    namespace mirrors the config and ``engine_overrides`` needs no
    per-flag mapping."""
    fields = {f.name: f for f in dataclasses.fields(EngineConfig)}
    for flag, name, extra in ENGINE_FLAGS:
        extra = dict(extra)
        default = extra.pop("default", fields[name].default)
        if isinstance(default, bool):
            ap.add_argument(flag, dest=name, action="store_true", **extra)
        else:
            ap.add_argument(flag, dest=name, type=type(default),
                            default=default, **extra)


def engine_overrides(args) -> dict:
    """The parsed values of every ``ENGINE_FLAGS`` knob, keyed by
    ``EngineConfig`` field name — splat into ``engine_config_for``."""
    return {name: getattr(args, name) for _, name, _ in ENGINE_FLAGS}


def skew_profile(moe, skew: float) -> np.ndarray:
    """Offline per-expert load profile under the synthetic skew router
    (core/router.py route_skewed): the first ``router_skew_experts``
    experts share ``skew`` of the mass, the rest split the remainder.
    Feeds ``static_opt_placement`` — the paper's profile-then-place
    baseline, which a live stream whose skew drifts then defeats."""
    E, H = moe.num_experts, moe.router_skew_experts
    p = np.full((E,), (1.0 - skew) / max(E - H, 1))
    p[:H] = skew / max(H, 1)
    return (p * 10_000).astype(np.int64)


def config_from_args(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if getattr(args, "sliding_window", -1) >= 0:
        # long-context cells: reduced() clamps archs like mixtral to a
        # 64-token window, which rejects paged pools longer than the
        # window; 0 disables the window so >=2k prompts can run paged
        cfg = cfg.replace(sliding_window=args.sliding_window)
    if cfg.moe is None:
        return cfg
    moe = dataclasses.replace(cfg.moe, policy=args.policy)
    if args.skew > 0:
        moe = dataclasses.replace(moe, router_skew=args.skew)
    if args.replica_slots > 0:
        moe = dataclasses.replace(moe, num_replica_slots=args.replica_slots)
    if args.q_tokens > 0:
        moe = dataclasses.replace(moe, q_tokens=args.q_tokens)
    if args.policy == "static_opt" and moe.num_experts >= args.model_par:
        # profile-then-place: bin-pack the offline skew profile once
        placement = static_opt_placement(
            skew_profile(moe, moe.router_skew), args.model_par)
        moe = dataclasses.replace(moe, placement=tuple(int(e)
                                                       for e in placement))
    return cfg.replace(moe=moe)


def _mesh_and_model(args, cfg, prompt_len):
    pcfg = ParallelConfig(attn_chunk=min(512, prompt_len))
    if args.data_par > 1:
        raise NotImplementedError(
            "the serving engine shards the model/expert axis only; "
            "--data-par must be 1 (data-parallel serving is an open item)")
    mesh = make_host_mesh(data=1, model=args.model_par)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, pcfg, batch=args.batch, seq_len=prompt_len,
                        mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    return mesh, model, params


def _engine_cfg(args, cfg, prompt_len, gen, role="unified"):
    return engine_config_for(
        cfg, max_slots=args.batch, prompt_len=prompt_len,
        max_new_tokens=gen, skew_seed=args.seed + 1,
        moe_policy=args.moe_policy or None, role=role,
        **engine_overrides(args))


def build_serving_engine(args, cfg=None, *, prompt_len=None, gen=None):
    """Config + model + engine from CLI args (shared with examples).

    ``prompt_len``/``gen`` override the CLI shapes (trace-driven runs size
    the engine from the trace, not the defaults)."""
    cfg = cfg if cfg is not None else config_from_args(args)
    prompt_len = prompt_len or args.prompt_len
    gen = gen or args.gen
    mesh, model, params = _mesh_and_model(args, cfg, prompt_len)
    ecfg = _engine_cfg(args, cfg, prompt_len, gen)
    engine = ServeEngine(model, params, ecfg, mesh=mesh)
    return cfg, engine


def build_fleet(args, cfg=None, *, prompt_len=None, gen=None):
    """N virtual replicas behind a ``FleetRouter``: one set of weights on
    one device group, one engine (and KV pool) each, one shared wall
    clock. ``--disaggregate`` makes the first ``replicas // 2`` engines
    prefill-role and the rest decode-role (requires ``--paged``)."""
    cfg = cfg if cfg is not None else config_from_args(args)
    prompt_len = prompt_len or args.prompt_len
    gen = gen or args.gen
    mesh, model, params = _mesh_and_model(args, cfg, prompt_len)
    if args.disaggregate:
        if args.replicas < 2:
            raise ValueError("--disaggregate needs --replicas >= 2 "
                             "(at least one prefill + one decode engine)")
        if not args.paged:
            raise ValueError("--disaggregate hands KV off through the "
                             "paged block machinery; add --paged")
        n_pf = max(1, args.replicas // 2)
        roles = ["prefill"] * n_pf + ["decode"] * (args.replicas - n_pf)
    else:
        roles = ["unified"] * args.replicas
    clock = WallClock()
    engines = [ServeEngine(model, params,
                           _engine_cfg(args, cfg, prompt_len, gen, role),
                           mesh=mesh, clock=clock)
               for role in roles]
    fleet = FleetRouter(engines, policy=args.routing_policy,
                        affinity_weight=args.affinity_weight)
    return cfg, fleet


def serve(args):
    cfg = config_from_args(args)
    if args.trace:
        requests = load_trace(args.trace, vocab_size=cfg.vocab_size)
        prompt_len = max(r.prompt_len for r in requests)
        gen = max(r.max_new_tokens for r in requests)
    else:
        n = args.requests or args.batch
        requests = poisson_requests(
            n, rate=args.rate, vocab_size=cfg.vocab_size,
            prompt_len=args.prompt_len, max_new_tokens=args.gen,
            seed=args.seed, shared_prefix_len=args.shared_prefix_len)
        prompt_len, gen = args.prompt_len, args.gen
    if args.replicas > 1 or args.disaggregate:
        return serve_fleet(args, cfg, requests, prompt_len, gen)
    cfg, engine = build_serving_engine(args, cfg, prompt_len=prompt_len,
                                       gen=gen)
    engine.warmup()                      # compile outside the TTFT window
    rep = engine.run(requests)

    ttft, tpot = rep["ttft"], rep["tpot"]
    print(f"[serve] arch={args.arch} policy={args.policy} skew={args.skew} "
          f"slots={args.batch} requests={rep['n_requests']} rate={args.rate}")
    print(f"[serve] TTFT p50 {ttft['p50'] * 1e3:.1f} ms  "
          f"p99 {ttft['p99'] * 1e3:.1f} ms   "
          f"TPOT p50 {tpot['p50'] * 1e3:.2f} ms   "
          f"decode {rep['throughput_tok_s']:.1f} tok/s "
          f"(occupancy {rep['mean_occupancy']:.2f}/{args.batch})")
    moe = rep.get("moe", {})
    if any(k.endswith("moved_units") for k in moe):
        for phase in ("prefill", "decode"):
            if f"{phase}/moved_units" not in moe:
                continue
            drops = moe.get(f"{phase}/send_drops", 0.0) \
                + moe.get(f"{phase}/dest_drops", 0.0)
            print(f"[serve] {phase} schedule: "
                  f"moved={moe[f'{phase}/moved_units']:.0f} "
                  f"drops={drops:.0f} "
                  f"max_load {moe.get(f'{phase}/max_load_before', 0):.0f}"
                  f"->{moe.get(f'{phase}/max_load_after', 0):.0f}")
    lb = rep.get("load_balance", {})
    for phase, sec in lb.items():
        if "max_mean_ratio" not in sec:
            continue
        print(f"[serve] {phase} load: max/mean ratio "
              f"{sec['max_mean_ratio']:.2f}  "
              f"straggler_wait {sec['straggler_wait_units']:.1f} units  "
              f"drops {sec.get('send_drops_total', 0):.0f}/"
              f"{sec.get('dest_drops_total', 0):.0f}")
    eng_rep = rep["engine"]
    if args.replica_slots:
        print(f"[serve] replication: slots={eng_rep['replica_slots']} "
              f"interval={eng_rep.get('rebalance_interval', 0)} "
              f"swaps={eng_rep.get('replica_swaps', 0)} "
              f"hot={eng_rep.get('hot_experts', [])}")
    if args.paged:
        util = rep.get("kv_utilization")
        print(f"[serve] paged KV: blocks={rep['engine']['num_kv_blocks']} "
              f"x{rep['engine']['kv_block_size']} tokens  "
              f"utilization={util if util is None else f'{util:.2f}'}  "
              f"preemptions={rep['preemptions']}  "
              f"max_concurrency={rep['max_occupancy']}  "
              f"fused_attention={rep['engine']['fused_paged_attention']}")
    if args.prefix_sharing:
        hit = rep.get("prefix_hit_rate")
        print(f"[serve] prefix cache: "
              f"hit_rate={hit if hit is None else f'{hit:.2f}'}  "
              f"cow_copies={rep['cow_copies']}  "
              f"evictions={rep['evictions']}  "
              f"resume_cached_tokens={rep['resume_cached_tokens']}")
    if getattr(args, "resident_experts", 0) and "residency" in rep:
        res = rep["residency"]
        hr = res.get("hit_rate")
        print(f"[serve] residency: budget={eng_rep['resident_experts']} "
              f"policy={eng_rep.get('prefetch_policy')}  "
              f"hit_rate={hr if hr is None else f'{hr:.2f}'}  "
              f"swaps={res['swaps']} prefetches={res['prefetches']}  "
              f"stall={res['stall_units']:.4f}s  "
              f"staged={res['bytes_staged'] / 1e6:.1f} MB")
    if args.speculative_k and "speculative" in rep:
        sp = rep["speculative"]
        acc = sp["acceptance_rate"]
        print(f"[serve] speculative k={args.speculative_k} "
              f"policy={args.speculative_policy}: "
              f"acceptance={acc if acc is None else f'{acc:.2f}'}  "
              f"tokens/step={sp['tokens_per_step']:.2f}  "
              f"steps/token={sp['steps_per_committed_token']:.2f}")
    print(f"[serve] jit entries {rep['jit_entries']} "
          f"recompiled_after_warmup={rep.get('recompiled_after_warmup')}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"[serve] report -> {args.out}")
    return rep


def serve_fleet(args, cfg, requests, prompt_len, gen):
    cfg, fleet = build_fleet(args, cfg, prompt_len=prompt_len, gen=gen)
    fleet.warmup()                       # compile outside the TTFT window
    rep = fleet.run(requests)
    fl = rep["fleet"]
    agg, routing, hand = fl["aggregate"], fl["routing"], fl["handoffs"]
    ttft, tpot = agg["ttft"], agg["tpot"]
    print(f"[fleet] arch={args.arch} replicas={fl['n_replicas']} "
          f"policy={routing['policy']} "
          f"disaggregated={fl['disaggregated']} "
          f"requests={agg['n_requests']} rate={args.rate}")
    print(f"[fleet] TTFT p50 {ttft['p50'] * 1e3:.1f} ms  "
          f"p99 {ttft['p99'] * 1e3:.1f} ms   "
          f"TPOT p50 {tpot['p50'] * 1e3:.2f} ms   "
          f"decode {agg['throughput_tok_s']:.1f} tok/s   "
          f"goodput {agg['goodput_req_s']:.2f} req/s")
    hit = routing["affinity_hit_rate"]
    print(f"[fleet] routing: per_replica={routing['per_replica']}  "
          f"affinity_hits={routing['affinity_hits']} "
          f"(rate={hit if hit is None else f'{hit:.2f}'}, "
          f"{routing['affinity_hit_tokens']} cached tokens)")
    if fl["disaggregated"]:
        print(f"[fleet] handoffs: moved={hand['moved']} "
              f"bytes={hand['bytes'] / 2 ** 20:.2f} MiB "
              f"pending={hand['pending']}")
    for r in fl["replicas"]:
        rt = r["ttft"]["p50"]
        print(f"[fleet]   replica {r['index']} role={r['role']:8s} "
              f"routed={r['routed']:3d} finished={r['n_requests']:3d} "
              f"steps={r['steps']:4d} "
              f"ttft_p50={'-' if rt is None else f'{rt * 1e3:.1f}ms'}")
    recompiled = [bool(rr.get("recompiled_after_warmup"))
                  for rr in rep["replica_reports"]]
    print(f"[fleet] recompiled_after_warmup={recompiled}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"[fleet] report -> {args.out}")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (concurrent requests)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--skew", type=float, default=0.0)
    ap.add_argument("--policy", default="harmoeny",
                    choices=["harmoeny", "round_robin", "even_split",
                             "static_opt"])
    ap.add_argument("--moe-policy", default="",
                    choices=["", "harmoeny", "round_robin", "even_split",
                             "static_opt"],
                    help="decode-time scheduling policy override (default: "
                         "--policy everywhere); lets one set of weights "
                         "serve prefill and decode under different policies")
    ap.add_argument("--q-tokens", type=int, default=0,
                    help="scheduler token-unit granularity override (0 = "
                         "auto threshold; small values let tiny decode "
                         "batches redistribute)")
    ap.add_argument("--data-par", type=int, default=0)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    # --- serving-engine knobs ---
    # every EngineConfig knob comes from the ENGINE_FLAGS table (one
    # declaration per knob, typed/defaulted from the dataclass field)
    add_engine_flags(ap)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default: one closed batch)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate req/s (0 = all at t=0)")
    ap.add_argument("--sliding-window", type=int, default=-1,
                    help="override the arch's sliding window (-1 = keep; "
                         "0 = full attention — needed for long-context "
                         "paged cells on reduced window archs)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="synthetic prompts share their first K tokens "
                         "(the system-prompt regime prefix caching targets)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the fleet router (>1 "
                         "enables fleet mode; virtual replicas share one "
                         "set of weights on one device group)")
    ap.add_argument("--routing-policy", default="load",
                    choices=list(ROUTING_POLICIES),
                    help="fleet routing: load = least queued+KV tokens, "
                         "prefix_affinity = load minus cached-prefix "
                         "match (needs --prefix-sharing to matter), "
                         "round_robin = baseline")
    ap.add_argument("--affinity-weight", type=float, default=1.0,
                    help="tokens of load one cached prefix token offsets "
                         "under prefix_affinity routing")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split the fleet into prefill-role and decode-"
                         "role engines linked by KV handoff (needs "
                         "--paged and --replicas >= 2)")
    ap.add_argument("--trace", default="",
                    help="JSON trace file of arrival records")
    ap.add_argument("--out", default="", help="write the report JSON here")
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
