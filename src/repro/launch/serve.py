"""Serving driver: batched prefill + decode with HarMoEny load balancing.

Example (CPU, small MoE, heavy synthetic skew):
  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 64 --gen 16 --skew 0.9 --model-par 4

Reports TTFT (prefill latency), decode tokens/s, and the HarMoEny schedule
diagnostics (moved units, drops, load balance) — the paper's §5 metrics.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.models.model import MeshShape, build_model


def serve(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.moe is not None and args.skew > 0:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, router_skew=args.skew, policy=args.policy))
    elif cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, policy=args.policy))
    pcfg = ParallelConfig(attn_chunk=min(512, args.prompt_len))
    n_dev = len(jax.devices())
    data = args.data_par or max(1, n_dev // max(args.model_par, 1))
    mesh = make_host_mesh(data=data, model=args.model_par)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, pcfg, batch=args.batch, seq_len=args.prompt_len,
                        mesh_shape=ms, mesh=mesh)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    if cfg.num_prefix_embeddings:
        batch["patches"] = jnp.zeros(
            (args.batch, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    if cfg.is_moe and args.skew > 0:
        batch["skew_key"] = jax.random.PRNGKey(args.seed)

    s_max = args.prompt_len + args.gen + cfg.num_prefix_embeddings + 8
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        prefill = jax.jit(lambda p, b: model.prefill(p, b, s_max=s_max))
        decode = jax.jit(model.decode_step)

        # warmup/compile excluded from TTFT
        logits, caches, pos, diags = jax.block_until_ready(
            prefill(params, batch))
        t0 = time.time()
        logits, caches, pos, diags = jax.block_until_ready(
            prefill(params, batch))
        ttft = time.time() - t0
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

        generated = [np.asarray(tok)]
        skew_key = jax.random.PRNGKey(args.seed + 1)
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, caches, pos, ddiags = decode(params, tok, caches, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = time.time() - t0
        tput = args.batch * (args.gen - 1) / max(dt, 1e-9)

    print(f"[serve] arch={args.arch} policy={args.policy} skew={args.skew}")
    print(f"[serve] TTFT {ttft * 1e3:.1f} ms   decode {tput:.1f} tok/s")
    if diags and "moved_units" in diags:
        print(f"[serve] prefill schedule: moved={float(np.mean(diags['moved_units'])):.0f} "
              f"drops={float(np.mean(diags['send_drops']) + np.mean(diags['dest_drops'])):.0f} "
              f"max_load {float(np.mean(diags['max_load_before'])):.0f}"
              f"->{float(np.mean(diags['max_load_after'])):.0f}")
    out = np.concatenate(generated, axis=1)
    print(f"[serve] generated shape {out.shape}; first row: {out[0][:12]}")
    return ttft, tput


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--skew", type=float, default=0.0)
    ap.add_argument("--policy", default="harmoeny",
                    choices=["harmoeny", "round_robin", "even_split"])
    ap.add_argument("--data-par", type=int, default=0)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
