"""Step functions (train / prefill / decode) assembled from model + optimizer.

Shared by train.py, serve.py, dryrun.py and the benchmarks so the compiled
artifact analyzed in the dry-run is exactly what the drivers run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


def make_train_step(model: Model, *, lr: float = 3e-4):
    """Training step with optional microbatch gradient accumulation.

    ``pcfg.microbatch`` > 1 scans over microbatches accumulating f32 grads
    and defers the (compressed) data-parallel reduction + optimizer update to
    the tail — the standard compute/comm-overlap schedule, and it bounds the
    per-step activation residuals to one microbatch (DESIGN.md §3).
    """
    n_ub = max(model.pcfg.microbatch, 1)

    def grads_of(params, batch, skew_key):
        def loss_fn(p):
            loss, diags = model.train_loss(p, batch, skew_key)
            return loss, diags
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state: AdamWState, batch, skew_key=None):
        if n_ub == 1:
            (loss, diags), grads = grads_of(params, batch, skew_key)
        else:
            ub_batch = jax.tree.map(
                lambda x: x.reshape((n_ub, x.shape[0] // n_ub) + x.shape[1:]),
                batch)

            def acc_step(acc, ub):
                (loss, diags), g = grads_of(params, ub, skew_key)
                g32 = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                   acc[0], g)
                return (g32, acc[1] + loss), diags

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), diags = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0.0)), ub_batch)
            grads = jax.tree.map(lambda g: g / n_ub, gsum)
            loss = loss_sum / n_ub
            diags = jax.tree.map(lambda d: d.mean(), diags)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
        return new_params, new_opt, loss, diags
    return train_step


def make_prefill_step(model: Model, *, s_max: int):
    def prefill_step(params, batch):
        logits, caches, pos, diags = model.prefill(params, batch, s_max=s_max)
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return token, caches, pos, diags
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, token, caches, pos):
        logits, new_caches, new_pos, diags = model.decode_step(
            params, token, caches, pos)
        new_token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return new_token, new_caches, new_pos, diags
    return decode_step


def optimizer_shapes(param_shapes: Any) -> AdamWState:
    return jax.eval_shape(adamw_init, param_shapes)
