"""Trip-count-aware HLO analysis for roofline terms.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan``'s while-body FLOPs are *not* multiplied by the trip count
(verified empirically; see EXPERIMENTS.md §Method). Since every stack in
this framework scans over layers, that undercounts compute by ~L x. This
module reparses ``compiled.as_text()`` and propagates loop multipliers:

  * while ops carry ``backend_config={"known_trip_count":{"n":...}}`` for
    counted loops (every lax.scan); unknown-trip loops (the HarMoEny
    scheduler's tiny rebalance loop) default to multiplier 1;
  * fusion/call ops propagate their caller's multiplier (fusion bodies are
    counted for FLOPs but not for bytes — operands/results of the fusion
    node itself model the HBM traffic, which is exactly XLA's own model);
  * FLOPs counted from dot ops (2 * prod(result) * prod(contracted dims)) —
    >99% of model compute; bytes from operand+result sizes of top-level ops;
    collective bytes from result sizes (x2 for all-reduce: ring RS+AG).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_instr(line: str) -> Optional[Tuple[str, str, str, str]]:
    """'%x = TYPE op(...), attrs' -> (name, type, op, rest). Handles tuple
    types containing /*index=N*/ comments via paren balancing."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, rest = rest[:i + 1], rest[i + 1:]
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    rest = rest.strip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    return name, type_str, om.group(1), rest[om.end():]
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instruction:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name, self.type_str, self.op, self.rest = name, type_str, op, rest


def parse_module(hlo: str) -> Dict[str, Dict[str, Any]]:
    """computation name -> {instrs: [Instruction], types: {name: type_str}}."""
    comps: Dict[str, Dict[str, Any]] = {}
    current: Optional[Dict[str, Any]] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if (line.startswith("%") or line.startswith("ENTRY")) and "->" in line \
                and line.endswith("{"):
            m = _COMP_RE.match(line)
            if m:
                current = {"instrs": [], "types": {}}
                comps[m.group(1)] = current
                # parameters: "name: type, name: type" (types may contain
                # commas inside brackets/parens — split carefully)
                params = m.group(2)
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))",
                                      params):
                    current["types"][pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            continue
        if current is None:
            continue
        parsed = _parse_instr(line)
        if parsed is None:
            continue
        name, type_str, op, rest = parsed
        current["instrs"].append(Instruction(name, type_str, op, rest))
        current["types"][name] = type_str
    return comps


def _find_entry(comps: Dict[str, Dict[str, Any]], hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fall back: computation never referenced by others
    referenced = set()
    for c in comps.values():
        for ins in c["instrs"]:
            for r in re.findall(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)",
                                ins.rest):
                referenced.add(r)
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _multipliers(comps: Dict[str, Dict[str, Any]], entry: str
                 ) -> Tuple[Dict[str, float], Dict[str, bool]]:
    mult: Dict[str, float] = defaultdict(float)
    fused: Dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    stack = [entry]
    seen_edges = set()
    while stack:
        comp = stack.pop()
        m = mult[comp]
        for ins in comps[comp]["instrs"]:
            targets: List[Tuple[str, float, bool]] = []
            if ins.op == "while":
                trip = 1.0
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"', ins.rest)
                if tm:
                    trip = float(tm.group(1))
                for key in ("body", "condition"):
                    bm = re.search(rf"{key}=%?([\w.\-]+)", ins.rest)
                    if bm:
                        targets.append((bm.group(1), m * trip,
                                        fused[comp]))
            elif ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if fm:
                    targets.append((fm.group(1), m, True))
            elif ins.op in ("call", "custom-call", "async-start"):
                fm = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                if fm:
                    targets.append((fm.group(1), m, fused[comp]))
            elif ins.op == "conditional":
                for bm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      ins.rest):
                    names = bm.group(1) or bm.group(2) or ""
                    for nm in re.findall(r"%?([\w.\-]+)", names):
                        targets.append((nm, m, fused[comp]))
            for tgt, tm_, fz in targets:
                if tgt not in comps:
                    continue
                edge = (comp, tgt)
                if mult[tgt] < tm_ or edge not in seen_edges:
                    mult[tgt] = max(mult[tgt], tm_)
                    fused[tgt] = fused[tgt] or fz
                    seen_edges.add(edge)
                    stack.append(tgt)
    return mult, fused


def _dot_flops(ins: Instruction, types: Dict[str, str]) -> float:
    result_elems = 1
    for d in _shape_dims(ins.type_str):
        result_elems *= d
    ops = re.findall(r"%([\w.\-]+)", ins.rest.split("),")[0])
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contracted = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    return 2.0 * result_elems * contracted


def analyze(hlo: str) -> Dict[str, Any]:
    """Trip-count-corrected {flops, bytes, collectives{...}} for one module."""
    comps = parse_module(hlo)
    entry = _find_entry(comps, hlo)
    mult, fused = _multipliers(comps, entry)

    flops = 0.0
    bytes_all = 0.0      # every non-fused op: unfused worst case
    bytes_dot = 0.0      # dot/conv/gather/scatter/collective traffic only:
                         # models the fused TPU target (elementwise chains
                         # stay in VMEM/registers; see EXPERIMENTS.md §Method)
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_counts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        types = comp["types"]
        for ins in comp["instrs"]:
            in_fusion = fused.get(cname, False)
            base = ins.op.replace("-start", "").replace("-done", "")
            is_coll = base in _COLLECTIVES and not ins.op.endswith("-done")
            if ins.op in ("dot", "dot-general"):
                flops += m * _dot_flops(ins, types)
            if ins.op in _SKIP_BYTES:
                continue
            if ins.op in ("dynamic-slice", "gather"):
                nbytes = 2 * _type_bytes(ins.type_str)
                dot_nbytes = nbytes
            elif ins.op in ("dynamic-update-slice", "scatter"):
                ops_ = re.findall(r"%([\w.\-]+)", ins.rest)
                upd = types.get(ops_[1]) if len(ops_) > 1 else None
                nbytes = 2 * _type_bytes(upd or ins.type_str)
                dot_nbytes = nbytes
            else:
                nbytes = _type_bytes(ins.type_str)
                for opn in re.findall(r"%([\w.\-]+)", ins.rest)[:8]:
                    t = types.get(opn)
                    if t:
                        nbytes += _type_bytes(t)
                dot_nbytes = nbytes if (
                    ins.op in ("dot", "dot-general", "convolution")
                    or is_coll) else 0.0
            if not in_fusion:
                bytes_all += m * nbytes
            # dots may live inside (CPU) wrapper fusions: count regardless
            bytes_dot += m * dot_nbytes
            if is_coll:
                factor = 2.0 if base == "all-reduce" else 1.0
                coll_bytes[base] += m * _type_bytes(ins.type_str) * factor
                coll_counts[base] += m
    return {
        "flops": flops,
        "bytes_accessed": bytes_dot,
        "bytes_all": bytes_all,
        "collective_bytes": sum(coll_bytes.values()),
        "collectives_per_kind": coll_bytes,
        "collective_counts": coll_counts,
    }
