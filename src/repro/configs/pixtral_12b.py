"""pixtral-12b [vlm]: pixtral-ViT frontend (stubbed to patch embeddings) + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="swiglu",
    num_prefix_embeddings=1024,  # stubbed ViT patch embeddings, prepended
    tie_embeddings=False,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
