"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,  # dense d_ff for the leading dense layer (moonlight style)
    vocab_size=163840,
    head_dim=128,
    act="swiglu",
    moe=MoEConfig(
        num_experts=64,
        num_experts_per_tok=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        first_dense_layers=1,
        policy="harmoeny",
        capacity_factor=1.25,
        num_foreign_slots=4,
    ),
    tie_embeddings=False,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
