"""Model / shape / parallelism configuration dataclasses.

Every architecture in the assignment is expressed as a ``ModelConfig``. The
full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); smoke tests use ``reduced()`` copies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """MoE sub-config. ``policy`` selects the scheduling policy of core/."""

    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_ff_expert: int = 0          # per-expert FFN hidden size
    num_shared_experts: int = 0   # dense experts applied to every token
    moe_layer_period: int = 1     # every k-th layer is MoE (1 = all)
    moe_layer_offset: int = 0     # first MoE layer index
    first_dense_layers: int = 0   # leading dense layers (moonshot style)
    policy: str = "harmoeny"      # harmoeny | round_robin | even_split | static_opt
    capacity_factor: float = 1.25
    num_foreign_slots: int = 4    # K extra expert slots per rank (0 for decode)
    # R static replica slots per rank: weight-resident copies of hot experts
    # swapped in between serving windows (serve/rebalance.py); the scheduler
    # treats a replica host as a local destination at zero foreign-slot cost
    num_replica_slots: int = 0
    # static_opt: profile-optimized expert->slot permutation [Ep] baked into
    # the topology (tuple so the frozen config stays hashable)
    placement: Optional[Tuple[int, ...]] = None
    q_tokens: int = 0             # 0 = derive from hardware constants (Eq. 4)
    router_skew: float = 0.0      # synthetic skew alpha (paper Sec 5.1.2)
    router_skew_experts: int = 1  # number of "hot" experts for synthetic skew


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128       # N (dstate)
    head_dim: int = 64         # P
    num_heads: int = 0         # derived if 0: expand*d_model // head_dim
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256      # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # derived if 0: d_model // num_heads

    # --- attention flavour ---
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention
    global_attn_every: int = 0      # gemma2: every k-th layer is global (rest local)
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_every: int = 0             # zamba2: shared attn block every k layers
    use_qk_norm: bool = False

    # --- MLP / norm ---
    act: str = "swiglu"             # swiglu | gelu | gelu_mlp
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = True
    post_norm: bool = False         # gemma2 uses pre+post norms

    # --- MoE / SSM sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- enc-dec / multimodal ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0        # whisper: 1500 frames
    num_prefix_embeddings: int = 0  # pixtral: image patch embeddings prepended

    # --- numerics / source provenance ---
    dtype: str = "bfloat16"
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True iff every sequence-mixing layer is sub-quadratic (DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # all-layers sliding window counts as sub-quadratic
        return self.sliding_window > 0 and self.global_attn_every == 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing (whisper is enc-dec)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                num_experts_per_tok=min(self.moe.num_experts_per_tok, 2),
                d_ff_expert=64 if self.moe.d_ff_expert else 0,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                num_foreign_slots=2,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, num_heads=0, chunk_size=32)
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            global_attn_every=self.global_attn_every and 2,
            attn_every=self.attn_every and 2,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 32) if self.encoder_seq_len else 0,
            num_prefix_embeddings=min(self.num_prefix_embeddings, 8)
            if self.num_prefix_embeddings else 0,
            moe=moe,
            ssm=ssm,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assignment: train_4k / prefill_32k / decode_32k / long_500k)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, plus the reason when skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is sharded on the production mesh."""

    fsdp: bool = False            # shard params/opt-state over 'data' too
    remat: str = "none"           # none | full | selective
    shard_kv_seq: bool = False    # long_500k: KV sequence over 'data'
    microbatch: int = 0           # >0: scan-accumulated microbatches w/ deferred psum
    compress_grads: bool = False  # int8 all-reduce
    use_pallas: bool = False      # pallas kernels (TPU target); False = XLA ref path
    pallas_strict: bool = False   # use_pallas explicitly required: an inapplicable
                                  # fused path raises (FusedPathUnavailable) instead
                                  # of silently falling back to the reference
    loss_chunk: int = 2048        # vocab-loss sequence chunk
    attn_chunk: int = 1024        # chunked-flash KV block
    moe_cf_pair: float = 2.0      # off-diagonal dispatch pair capacity factor
    moe_block_m: int = 128        # grouped-FFN row-tile (weight reuse ~ block_m)
