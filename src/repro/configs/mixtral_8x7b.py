"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    sliding_window=4096,  # all layers SWA => sub-quadratic, long_500k runs
    act="swiglu",
    moe=MoEConfig(
        num_experts=8,
        num_experts_per_tok=2,
        d_ff_expert=14336,
        policy="harmoeny",
        capacity_factor=1.25,
        num_foreign_slots=2,
    ),
    tie_embeddings=False,
    source="arXiv:2401.04088; hf",
)
