"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed to frame embeddings.

32L (decoder) d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    act="gelu_mlp",
    norm="layernorm",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, no RoPE
    is_encoder_decoder=True,
    encoder_layers=32,
    encoder_seq_len=1500,   # 30 s of audio at 50 Hz after the (stubbed) conv frontend
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
