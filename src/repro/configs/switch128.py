"""switch128 — the paper's own Switch Transformer (T5-style, 128 experts).

12 transformer blocks alternating MoE / dense; 128 experts per MoE block;
expert ~18 MB (paper Table 1). Used for paper-claim validation benchmarks.
[arXiv:2101.03961 + HarMoEny Table 1]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="switch128",
    family="moe",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32128,
    head_dim=64,
    act="gelu_mlp",
    norm="rmsnorm",
    moe=MoEConfig(
        num_experts=128,
        num_experts_per_tok=1,     # switch routing: top-1
        d_ff_expert=3072,          # 2*768*3072*4B ≈ 18.9 MB/expert fp32 (paper: 18 MB)
        moe_layer_period=2,        # alternate MoE / dense blocks
        moe_layer_offset=1,
        policy="harmoeny",
        capacity_factor=1.25,
        num_foreign_slots=4,
    ),
    tie_embeddings=True,
    source="paper model; arXiv:2101.03961",
)
