"""mamba2-2.7b [ssm]: SSD (state-space duality), attention-free.

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,       # attention-free
    num_kv_heads=0,
    d_ff=0,            # no MLP: mamba2 blocks only
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
