from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, ParallelConfig,
    SHAPES, SHAPE_BY_NAME, shape_applicable, round_up,
)
from repro.configs.registry import (
    ASSIGNED, PAPER_MODELS, REGISTRY, get_config, iter_cells,
)
