"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    attn_every=6,  # a shared-weight attention(+MLP) block every 6 mamba layers
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    act="swiglu",
    source="arXiv:2411.15242; unverified",
)
