"""qwen15-moe — the paper's QWEN model (Qwen1.5-MoE, 24 blocks x 60 experts).

Expert ~33 MB (paper Table 1): 3*1408*2048*4B ≈ 34.6 MB fp32.
[hf:Qwen/Qwen1.5-MoE-A2.7B + HarMoEny Table 1]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen15-moe-a27b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,
    vocab_size=151936,
    head_dim=128,
    act="swiglu",
    moe=MoEConfig(
        num_experts=60,
        num_experts_per_tok=4,
        d_ff_expert=1408,
        num_shared_experts=4,
        policy="harmoeny",
        capacity_factor=1.25,
        num_foreign_slots=4,
    ),
    tie_embeddings=False,
    source="paper model; hf:Qwen/Qwen1.5-MoE-A2.7B",
)
