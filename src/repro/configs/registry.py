"""Architecture registry: --arch <id> resolution for launchers/tests/benchmarks."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, SHAPES, SHAPE_BY_NAME, shape_applicable

from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.switch128 import CONFIG as _switch128
from repro.configs.qwen15_moe_a27b import CONFIG as _qwen

# The ten assigned architectures (the dry-run matrix iterates these).
ASSIGNED: Dict[str, ModelConfig] = {
    "whisper-large-v3": _whisper,
    "zamba2-7b": _zamba2,
    "gemma2-2b": _gemma2,
    "mistral-nemo-12b": _nemo,
    "phi4-mini-3.8b": _phi4,
    "stablelm-1.6b": _stablelm,
    "moonshot-v1-16b-a3b": _moonshot,
    "mixtral-8x7b": _mixtral,
    "mamba2-2.7b": _mamba2,
    "pixtral-12b": _pixtral,
}

# The paper's own models, used by the claim-validation benchmarks.
PAPER_MODELS: Dict[str, ModelConfig] = {
    "switch128": _switch128,
    "qwen15-moe-a27b": _qwen,
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def iter_cells(include_skipped: bool = False):
    """Yield (arch, shape, runnable, skip_reason) over the 10x4 assignment matrix."""
    for arch, cfg in ASSIGNED.items():
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch, shape, ok, why


__all__ = [
    "ASSIGNED", "PAPER_MODELS", "REGISTRY", "get_config", "iter_cells",
    "SHAPES", "SHAPE_BY_NAME",
]
