#!/usr/bin/env bash
# Tier-1 suite + a 2-device CPU serving smoke (the ISSUE acceptance path).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== 2-device CPU serve smoke (slab) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20

echo "== 2-device CPU serve smoke (paged KV + top-k sampling) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20 \
    --paged --kv-block-size 8 --temperature 0.7 --top-k 20

echo "smoke OK"
