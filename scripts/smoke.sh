#!/usr/bin/env bash
# Tier-1 suite + a 2-device CPU serving smoke (the ISSUE acceptance path).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== 2-device CPU serve smoke (slab) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20

echo "== 2-device CPU serve smoke (paged KV + top-k sampling) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20 \
    --paged --kv-block-size 8 --temperature 0.7 --top-k 20

echo "== 2-device CPU serve smoke (paged KV + fused Pallas decode attention) =="
# --fused-attention: the paged-attention kernel runs in interpret mode on
# CPU; greedy decode here must match the gather-reference cell token-wise
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20 \
    --paged --kv-block-size 8 --fused-attention

echo "== 2-device CPU serve smoke (prefix-sharing KV cache + top-p) =="
# --prefill-chunk 16: sharing pads the logical pool by one extra chunk,
# which must still fit the reduced model's 64-token sliding window
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
python -m repro.launch.serve --arch mixtral-8x7b --reduced --model-par 2 \
    --skew 0.9 --prompt-len 32 --gen 8 --requests 6 --rate 20 \
    --paged --kv-block-size 8 --prefill-chunk 16 \
    --prefix-sharing --shared-prefix-len 24 \
    --temperature 0.7 --top-p 0.9

echo "smoke OK"
