#!/usr/bin/env bash
# Tier-1 suite + a 2-device CPU serving smoke (the ISSUE acceptance path).
#
# Fail-fast: -e aborts on the first failing command, -u on unset vars,
# -o pipefail on any failure inside a pipeline, -E so the ERR trap fires
# inside the serve() function too; every serve invocation runs under a
# named CELL so a CI failure attributes to the right cell (the ERR trap
# prints it) instead of just "smoke.sh exited 1".
set -Eeuo pipefail
cd "$(dirname "$0")/.."

CELL="tier-1 tests"
trap 'echo "smoke FAILED in cell: ${CELL}" >&2' ERR

echo "== tier-1 tests =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

serve() {
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --model-par 2 --skew 0.9 --prompt-len 32 --gen 8 \
        --requests 6 --rate 20 "$@"
}

CELL="slab"
echo "== 2-device CPU serve smoke (slab) =="
serve

CELL="paged + top-k sampling"
echo "== 2-device CPU serve smoke (paged KV + top-k sampling) =="
serve --paged --kv-block-size 8 --temperature 0.7 --top-k 20

CELL="paged + fused attention"
echo "== 2-device CPU serve smoke (paged KV + fused Pallas decode attention) =="
# --fused-attention: the paged-attention kernel runs in interpret mode on
# CPU; greedy decode here must match the gather-reference cell token-wise
serve --paged --kv-block-size 8 --fused-attention

CELL="prefix sharing + top-p"
echo "== 2-device CPU serve smoke (prefix-sharing KV cache + top-p) =="
# --prefill-chunk 16: sharing pads the logical pool by one extra chunk,
# which must still fit the reduced model's 64-token sliding window
serve --paged --kv-block-size 8 --prefill-chunk 16 \
    --prefix-sharing --shared-prefix-len 24 \
    --temperature 0.7 --top-p 0.9

CELL="speculative decode"
echo "== 2-device CPU serve smoke (paged KV + speculative decode) =="
# --speculative-k 3: self-drafting verify window; the padded pool grows
# by k tokens, which must still fit the 64-token sliding window
serve --paged --kv-block-size 8 --prefill-chunk 16 --speculative-k 3

CELL="speculative + fused multi-query kernel"
echo "== 2-device CPU serve smoke (speculative + fused multi-query kernel) =="
serve --paged --kv-block-size 8 --prefill-chunk 16 --speculative-k 3 \
    --fused-attention

CELL="long-context fused prefill (q-tiled)"
echo "== 2-device CPU serve smoke (1k prompt, fused q-tiled prefill + fused MoE) =="
# --sliding-window 0 lifts the reduced model's 64-token window (a 1k-token
# paged pool cannot fit it); --fused-attention then runs chunked prefill
# through the q-tiled slab-as-pool kernel in STRICT mode (a silent
# reference fallback would raise FusedPathUnavailable), and --fused-moe
# routes the expert FFN through the grouped-GEMM kernel. Smaller request
# count: interpret-mode q-tiled prefill is the slow cell.
serve --paged --kv-block-size 64 --prefill-chunk 128 --prompt-len 1024 \
    --requests 2 --sliding-window 0 --fused-attention --fused-moe

CELL="SSM slot state pool (mamba2)"
echo "== CPU serve smoke (mamba2 SSM, slotted recurrent-state pool) =="
# recurrent-state family: the engine picks the SlotStateStore (fixed
# per-slot SSM state, prefill-continuation carry, scratch reset between
# requests); --skew/--policy are ignored for a moe-less config, and the
# paged pool is rejected for this family so the cell stays slab
serve --arch mamba2-2.7b --model-par 1 --requests 4

CELL="sliding-window ring (prompt beyond window)"
echo "== 2-device CPU serve smoke (paged ring, 96-token prompts > 64-token window) =="
# prompts beyond the reduced model's 64-token sliding window used to be
# a loud rejection in the paged engine; window-clamped layers now serve
# as fixed-size ring-buffer chains (allocated whole at admission, never
# grown), token-identical to the windowed slab oracle
serve --paged --kv-block-size 8 --prompt-len 96 --requests 4

# Skew cells: same heavy-skew stream (--skew 0.9 is already the serve()
# default above) through the round_robin baseline and the HarMoEny
# schedule; --q-tokens 1 so decode-scale batches clear the movement
# granularity. The replication cell additionally swaps the EMA-hot
# expert into a static replica slot between windows — one decode jit
# entry across swaps is asserted by tests/test_serve_rebalance.py; here
# the cell just has to serve the stream without drops.
CELL="skew: round_robin baseline"
echo "== 2-device CPU serve smoke (skew 0.9, round_robin dispatch) =="
serve --paged --kv-block-size 8 --moe-policy round_robin --q-tokens 1

CELL="skew: harmoeny schedule"
echo "== 2-device CPU serve smoke (skew 0.9, harmoeny schedule) =="
serve --paged --kv-block-size 8 --moe-policy harmoeny --q-tokens 1

CELL="skew: harmoeny + hot-expert replication"
echo "== 2-device CPU serve smoke (skew 0.9, harmoeny + replication) =="
serve --paged --kv-block-size 8 --moe-policy harmoeny --q-tokens 1 \
    --replica-slots 1 --rebalance-interval 4

# Fleet cells: 2 virtual replicas (one set of weights, one engine + KV
# pool each) behind the FleetRouter on one shared clock. Load-only vs
# prefix-affinity routing on a shared-prefix stream, then one
# disaggregated cell (prefill-role -> decode-role KV handoff). A
# 1-replica fleet is bit-identical to the bare engine and disaggregation
# is token-identical to unified serving — both asserted by
# tests/test_serve_fleet.py; here the cells have to serve the stream and
# print populated fleet routing / handoff reports.
CELL="fleet: 2 replicas, load routing"
echo "== 2-device CPU serve smoke (fleet: 2 replicas, load routing) =="
serve --paged --kv-block-size 8 --prefill-chunk 16 \
    --prefix-sharing --shared-prefix-len 24 \
    --replicas 2 --routing-policy load

CELL="fleet: 2 replicas, prefix-affinity routing"
echo "== 2-device CPU serve smoke (fleet: 2 replicas, prefix-affinity) =="
serve --paged --kv-block-size 8 --prefill-chunk 16 \
    --prefix-sharing --shared-prefix-len 24 \
    --replicas 2 --routing-policy prefix_affinity --affinity-weight 3

CELL="fleet: disaggregated prefill/decode"
echo "== 2-device CPU serve smoke (fleet: prefill/decode disaggregation) =="
serve --paged --kv-block-size 8 --prefill-chunk 16 \
    --replicas 2 --disaggregate

CELL="tiered residency: predictive prefetch"
echo "== 2-device CPU serve smoke (tiered residency, predictive prefetch) =="
# --resident-experts 4 of the reduced model's 8 expert rows (W=2 per
# rank): half the expert footprint stays HBM-resident, the rest streams
# from the emulated host tier through the double-buffered staging
# scatter. Greedy streams stay token-identical across budgets (asserted
# by tests/test_serve_residency.py); here the cell has to serve the
# stream and print a populated residency report.
serve --paged --kv-block-size 8 --moe-policy harmoeny --q-tokens 1 \
    --resident-experts 4 --prefetch-policy predictive

echo "smoke OK"
