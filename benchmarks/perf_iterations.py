"""Reproduce the §Perf hillclimb measurements (EXPERIMENTS.md).

Each entry recompiles one cell in a subprocess with a config/flag variant
and prints the dominant-term before/after. Baselines are the committed
results/perf/*_baseline.json snapshots.

  PYTHONPATH=src python -m benchmarks.perf_iterations
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")
PERF = os.path.join(ROOT, "results", "perf")


def _coll(d):
    return d["collectives"]["total"]


def _show(tag, base_path, opt: dict, term_of, unit="GB"):
    with open(base_path) as f:
        base = json.load(f)
    b, a = term_of(base), term_of(opt)
    print(f"{tag}: {b / 1e9:.1f} {unit} -> {a / 1e9:.1f} {unit} "
          f"({b / max(a, 1e-9):.2f}x)")


def pair1_nemo():
    """SP disabled in train mode (iteration 1.2) — current code default, so
    a plain recompile shows the optimized state."""
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    from repro.launch.dryrun import lower_cell
    r = lower_cell("mistral-nemo-12b", "train_4k", verbose=False)
    print("RESULT" + json.dumps(r))
    """
    out = _run(code)
    opt = json.loads(out.split("RESULT")[1])
    _show("pair1 nemo train_4k collective bytes/dev",
          os.path.join(PERF, "nemo_train_baseline.json"), opt, _coll)


def pair2_moonshot():
    """cf_pair 1.25 + K=2 (iteration 2.1)."""
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import dataclasses, json
    import repro.launch.dryrun as DR
    from repro.configs import registry
    cfg = registry.REGISTRY["moonshot-v1-16b-a3b"]
    registry.REGISTRY["moonshot-v1-16b-a3b"] = cfg.replace(
        moe=dataclasses.replace(cfg.moe, num_foreign_slots=2))
    orig = DR.parallel_config
    DR.parallel_config = lambda c, s: dataclasses.replace(
        orig(c, s), moe_cf_pair=1.25)
    r = DR.lower_cell("moonshot-v1-16b-a3b", "prefill_32k", verbose=False)
    print("RESULT" + json.dumps(r))
    """
    out = _run(code)
    opt = json.loads(out.split("RESULT")[1])
    _show("pair2 moonshot prefill_32k a2a bytes/dev",
          os.path.join(PERF, "moonshot_prefill_baseline.json"), opt,
          lambda d: d["collectives"]["per_kind"]["all-to-all"])


def pair3_whisper():
    """Flash-kernel memory credit (iteration 3.1) — analytic; see
    EXPERIMENTS.md §Method for why Pallas cannot lower on the CPU backend."""
    with open(os.path.join(PERF, "whisper_prefill_baseline.json")) as f:
        base = json.load(f)
    B_loc, S, H, hd, chunk, enc_S = 2, 32768, 20, 64, 1024, 1500

    def score_bytes(Sq, Sk, heads):
        n_chunks = -(-Sk // chunk)
        return n_chunks * (B_loc * heads * Sq * min(chunk, Sk)) * 4 * 2
    credit = (32 * score_bytes(S, S, H) + 32 * score_bytes(S, enc_S, H)
              + 32 * score_bytes(enc_S, enc_S, H))
    b = base["bytes_accessed"]
    print(f"pair3 whisper prefill_32k memory bytes/dev: "
          f"{b / 1e12:.2f} TB -> {(b - credit) / 1e12:.2f} TB "
          f"({b / (b - credit):.1f}x, kernel-target accounting)")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return r.stdout


if __name__ == "__main__":
    pair3_whisper()
    pair1_nemo()
    pair2_moonshot()
