"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = simulated
per-MoE-layer latency; derived = the figure's headline metric). The schedule
under test is the REAL jitted scheduler; timing uses the calibrated v5e model
(core/simulator.py) — see DESIGN.md §8 for why wall-clock on 1 CPU core with
fake devices is not reported as a claim.

  PYTHONPATH=src python -m benchmarks.run            # all figures
  PYTHONPATH=src python -m benchmarks.run fig7_8     # one figure
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import (BenchSetup, model_tokens_per_s, run_policy,
                               skewed_counts)

POLICIES = ("harmoeny", "round_robin", "even_split", "static_opt")
ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


# ----------------------------------------------------------------------
def fig1_2_ecdf():
    """Paper Fig. 1/2: token-placement skew across experts and ranks."""
    rng = np.random.default_rng(0)
    for arch in ("switch128", "qwen15-moe-a27b"):
        setup = BenchSetup(arch=arch)
        counts = skewed_counts(rng, setup, alpha=0.0, dataset="zipf")
        per_e = np.sort(counts.sum(axis=0))[::-1].astype(float)
        share3 = per_e[:3].sum() / per_e.sum()
        emit(f"ecdf_expert_top3share_{arch}", 0.0, f"{share3:.3f}")
        for policy in ("round_robin", "harmoeny"):
            _, m = run_policy(counts, setup, policy)
            emit(f"ecdf_rank_imbalance_{arch}_{policy}",
                 m["layer_s"] * 1e6, f"maxload/mean={m['imbalance']:.3f}")


def fig5_11_breakdown():
    """Paper Fig. 5/11: per-rank idle time with 90% skew on 10 experts;
    rebalancing cuts GPU waiting from >80% to ~1-3%."""
    rng = np.random.default_rng(1)
    for arch in ("switch128", "qwen15-moe-a27b"):
        setup = BenchSetup(arch=arch)
        counts = skewed_counts(rng, setup, alpha=0.9, n_hot=10)
        for policy in ("round_robin", "harmoeny"):
            _, m = run_policy(counts, setup, policy)
            emit(f"breakdown_idle_{arch}_{policy}", m["layer_s"] * 1e6,
                 f"idle_mean={m['idle_frac_mean']:.3f};"
                 f"fetch_us={m['fetch_s'] * 1e6:.1f};"
                 f"sched_us={m['sched_s'] * 1e6:.1f};"
                 f"a2a_us={m['a2a_s'] * 1e6:.1f}")


def fig7_8_skew_sweep():
    """Paper Fig. 7/8: throughput and TTFT-shaped latency vs artificial
    skew (constant dataset), all four policies."""
    rng = np.random.default_rng(2)
    for arch in ("switch128", "qwen15-moe-a27b"):
        setup = BenchSetup(arch=arch)
        for alpha in (0.0, 0.5, 0.9):
            counts = skewed_counts(rng, setup, alpha=alpha)
            for policy in POLICIES:
                _, m = run_policy(counts, setup, policy)
                tput = model_tokens_per_s(m, setup)
                emit(f"skew{int(alpha * 100):02d}_{arch}_{policy}",
                     m["layer_s"] * 1e6,
                     f"tok/s={tput:.0f};drops={m['dropped']:.0f}")


def fig9_10_fluctuation():
    """Paper Fig. 9/10: per-batch random skew in [0, 0.95]; HarMoEny keeps
    throughput variance low while baselines swing."""
    rng = np.random.default_rng(3)
    setup = BenchSetup(arch="switch128")
    n_batches = 60
    alphas = rng.uniform(0.0, 0.95, n_batches)
    for policy in POLICIES:
        tputs, swaps = [], []
        for a in alphas:
            counts = skewed_counts(rng, setup, alpha=float(a))
            _, m = run_policy(counts, setup, policy)
            tputs.append(model_tokens_per_s(m, setup))
            swaps.append(m["moved"])
        tputs = np.array(tputs)
        emit(f"fluct_{policy}", float(1e6 / max(tputs.mean(), 1e-9)),
             f"mean_tok/s={tputs.mean():.0f};var={tputs.var():.1f};"
             f"cv={tputs.std() / tputs.mean():.4f};"
             f"mean_moved={np.mean(swaps):.0f}")


def fig12_13_policy_ablation():
    """Paper Fig. 12/13: policies on real-ish (zipf/random/constant) data."""
    rng = np.random.default_rng(4)
    for dataset in ("zipf", "random", "constant"):
        setup = BenchSetup(arch="switch128")
        counts = skewed_counts(rng, setup, alpha=0.0, dataset=dataset)
        for policy in POLICIES:
            _, m = run_policy(counts, setup, policy)
            emit(f"policy_{dataset}_{policy}", m["layer_s"] * 1e6,
                 f"tok/s={model_tokens_per_s(m, setup):.0f};"
                 f"imb={m['imbalance']:.2f};drops={m['dropped']:.0f}")


def eq4_q_threshold():
    """Paper §4.4/Eq.4: latency vs q. Too-small q fetches experts for tiny
    chunks; too-large q leaves imbalance unrepaired."""
    rng = np.random.default_rng(5)
    base = BenchSetup(arch="switch128")
    counts = skewed_counts(rng, base, alpha=0.7, n_hot=4)
    for q in (1, 4, 16, 64, 256, 1024, 4096):
        setup = BenchSetup(arch="switch128", q=q)
        _, m = run_policy(counts, setup, "harmoeny")
        emit(f"qthresh_q{q}", m["layer_s"] * 1e6,
             f"fetch_us={m['fetch_s'] * 1e6:.1f};"
             f"imb={m['imbalance']:.2f};moved={m['moved']}")


def capacity_drops():
    """TPU-native restatement (DESIGN.md §2): tokens dropped vs capacity
    factor under 90% skew — HarMoEny compiles at cf~1.25 with zero drops."""
    rng = np.random.default_rng(6)
    for cf in (1.0, 1.25, 2.0, 4.0):
        setup = BenchSetup(arch="switch128", cf_pair=cf)
        counts = skewed_counts(rng, setup, alpha=0.9)
        for policy in ("harmoeny", "round_robin"):
            _, m = run_policy(counts, setup, policy)
            emit(f"capacity_cf{cf}_{policy}", m["layer_s"] * 1e6,
                 f"drops={m['dropped']:.0f};imb={m['imbalance']:.2f}")


def kernel_microbench():
    """Pallas kernel correctness + op-count proxy (interpret mode; real MXU
    timing requires TPU hardware — see EXPERIMENTS.md §Method)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.moe_gmm.ops import fused_expert_ffn
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    from repro.kernels.moe_gmm.ops import tile_group_map
    bm, d, f, G, M = 8, 64, 128, 4, 64
    sizes = jnp.array([16, 16, 16, 16], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(0), (M, d))
    w_in = jax.random.normal(jax.random.PRNGKey(1), (G, d, f)) * 0.1
    w_out = jax.random.normal(jax.random.PRNGKey(2), (G, f, d)) * 0.1
    t0 = time.time()
    out = fused_expert_ffn(x, w_in, w_out, sizes, act="gelu", block_m=bm,
                           block_f=64, interpret=True)
    dt = time.time() - t0
    ref = moe_gmm_ref(x, w_in, w_out, tile_group_map(sizes, M // bm, bm),
                      act="gelu", block_m=bm)
    err = float(jnp.abs(out - ref).max())
    emit("kernel_moe_gmm_interpret", dt * 1e6, f"max_err={err:.2e}")


ALL = {
    "fig1_2": fig1_2_ecdf,
    "fig5_11": fig5_11_breakdown,
    "fig7_8": fig7_8_skew_sweep,
    "fig9_10": fig9_10_fluctuation,
    "fig12_13": fig12_13_policy_ablation,
    "eq4": eq4_q_threshold,
    "capacity": capacity_drops,
    "kernels": kernel_microbench,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        ALL[name]()


if __name__ == "__main__":
    main()
