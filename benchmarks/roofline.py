"""Roofline analysis from the dry-run matrix (assignment §ROOFLINE).

Reads results/dryrun/<arch>__<shape>__single.json and derives, per cell:

  compute   = HLO_FLOPs   / (chips * 197e12)      [s]
  memory    = HLO_bytes   / (chips * 819e9)       [s]
  collective= coll_bytes  / (chips * 50e9)        [s]

FLOPs/bytes/collective-bytes are the trip-count-corrected per-device values
(launch/hlo_analysis.py) multiplied back to all chips. MODEL_FLOPS is the
analytic 6*N(_active)*D (train) / 2*N*D (inference). The dominant term is the
bottleneck the §Perf loop iterates on.

  PYTHONPATH=src python -m benchmarks.roofline [--json]
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells(tag="single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{tag}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(cell):
    chips = cell["n_chips"]
    # corrected values are per-device; terms are per-chip times directly
    t_comp = cell["flops"] / PEAK_FLOPS
    t_mem = cell["bytes_accessed"] / HBM_BW
    t_coll = cell["collectives"]["total"] / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    hlo_flops_total = cell["flops"] * chips
    mf = cell.get("model_flops", 0.0)
    useful = mf / hlo_flops_total if hlo_flops_total else 0.0
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful model FLOPs per chip-second at the bound
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom, "model_flops": mf,
        "useful_flops_ratio": useful, "roofline_fraction": frac,
        "peak_gb": (cell["memory"]["peak_bytes"] or 0) / 1e9,
        "temp_gb": (cell["memory"]["temp_bytes"] or 0) / 1e9,
    }


def main():
    cells = load_cells("single")
    rows = [roofline_row(c) for c in cells]
    if "--json" in sys.argv:
        print(json.dumps(rows, indent=2))
        return
    hdr = (f"{'arch':<22}{'shape':<13}{'comp_s':>10}{'mem_s':>10}"
           f"{'coll_s':>10} {'dominant':<11}{'useful':>8}{'roofl%':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']:<22}{r['shape']:<13}"
              f"{r['t_compute_s']:>10.4f}{r['t_memory_s']:>10.4f}"
              f"{r['t_collective_s']:>10.4f} {r['dominant']:<11}"
              f"{r['useful_flops_ratio']:>8.3f}"
              f"{100 * r['roofline_fraction']:>7.1f}%")


if __name__ == "__main__":
    main()
