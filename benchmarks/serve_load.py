"""Serving load benchmark: arrival rate × router skew × policy sweep, plus
a paged-vs-slab KV capacity comparison and a shared-prefix trace, both at
equal memory.

Runs the repro.serve continuous-batching engine on a reduced Mixtral-family
MoE over 2 CPU-emulated devices (model/expert-parallel) and emits a
machine-readable ``BENCH_serve.json``:

* ``results`` — per-cell TTFT/TPOT percentiles, decode tokens/s, occupancy,
  KV utilization / effective concurrency, and HarMoEny schedule
  diagnostics, for the paged engine across rate × skew × policy;
* ``capacity`` — slab vs paged engines given the SAME physical KV token
  budget on a mixed-prompt-length workload: the paged pool's block-level
  allocation sustains strictly more concurrent decodes than the slab
  pool's worst-case slots;
* ``prefix`` — shared-prefix traces (common system prompt; also identical
  full prompts) through the paged engine with prefix sharing on vs off at
  the same block budget: sharing serves the common prefix out of the
  copy-on-write block cache, cutting prefill chunks and TTFT p50, with
  ``prefix_hit_rate``/``cow_copies`` reported per cell;
* ``speculative`` — self-drafting speculative decode (n-gram prompt
  lookup + static-shape ``[B, k+1]`` verify) on a repetitive-text
  workload vs the plain-decode baseline, plus an incompressible-random
  contrast cell: acceptance rate, committed tokens per slot-step, and
  decode steps per committed token (< 1.0 = the speculative win);
* ``skew`` — serving-time MoE load balancing under heavy router skew:
  harmoeny + hot-expert replication vs harmoeny / round_robin /
  even_split / static_opt at an equal capacity budget
  (capacity_factor 1.25).  Real-engine cells carry wall TTFT/tok_s, the
  measured max/mean rank-load ratio, the straggler-wait GPU-idle proxy,
  and drop counts; modeled cells cost each step's real schedule over a
  live drifting stream with the calibrated v5e time model, where the
  headline is harmoeny+replication beating the next-best baseline on
  decode throughput;
* ``residency`` — tiered expert residency (host↔HBM streaming) at a
  bounded working-set budget: real-engine cells carry the live
  ``residency`` report (hit rate, swaps, prefetches, staged bytes,
  modeled PCIe stall) across prefetch policies at half the expert
  footprint, and modeled cells cost a paper-scale drifting stream with
  the real scheduler under ``non_local`` demotion — the headline is
  predictive prefetch stalling strictly less than on-demand staging at
  the same budget while recovering ~all fully-resident throughput;
* ``fleet`` — multi-replica serving through the ``FleetRouter``:
  prefix-affinity routing vs load-only / round-robin on a two-group
  shared-prefix trace (affinity pins each prefix group to the replica
  whose radix cache holds it — fewer cold prefill chunks, lower TTFT
  p50), and prefill/decode disaggregation vs two unified replicas under
  a steady-decode + long-prompt-burst mix (the decode-role replica never
  runs prompt prefills, so burst prefill chunks cannot stall in-flight
  decodes — lower TPOT p99 at equal device count);
* ``state_pool`` — sequence-state stores under long-context + bursty
  pressure through the ``SequenceStateStore`` surface: a pure-SSM
  (mamba2) engine on the slotted recurrent-state pool under smooth vs
  bursty arrivals at the same mean rate (bursts oversubscribe the fixed
  slot pool, visible in TTFT p99, while ``state_bytes_per_slot`` stays
  length-independent), a hybrid (zamba2-style) long/short prompt mix,
  and the paged engine serving prompts far beyond its sliding window as
  fixed-size ring-buffer chains (O(window) KV per slot, chains never
  grow);
* ``decode_attention`` — microbench of the per-step decode-attention
  primitive, reference block-table gather vs the fused Pallas kernel,
  sweeping the active sequence length against ``L_max``: the reference
  materializes every row's full ``[L_max]`` logical K/V view regardless
  of actual length (constant bytes), the fused kernel touches only the
  valid blocks (bytes scale with the active length);
* ``phases`` — per-phase serving breakdown (prefill / prefix-tail /
  decode / verify tokens-per-second and analytic KV bytes touched) with
  the unified fused path on vs the reference gather, at equal config:
  a long-context (2k-prompt, window disabled) prefix-sharing cell and a
  speculative-verify cell.  The fused cells assert that no hot phase
  dispatches the logical gather (``attention_dispatch`` is fused on
  every traced branch, ``attention_fallbacks`` empty).  Off-TPU the
  fused kernels run in interpret mode, so wall tokens/s are reported
  but the comparison carries on the analytic bytes and the clearly
  labeled ``modeled_roofline_tok_s`` (bytes / v5e HBM bandwidth);
  on TPU the wall columns are real.

  PYTHONPATH=src python benchmarks/serve_load.py [--out BENCH_serve.json]
"""
import argparse
import functools
import json
import os
import platform
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses                                            # noqa: E402

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.configs.base import ParallelConfig                 # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402
from repro.models.model import MeshShape, build_model         # noqa: E402
from repro.serve import (FleetRouter, ServeEngine, WallClock,  # noqa: E402
                         bursty_requests, engine_config_for,
                         long_context_requests, merge_requests,
                         poisson_requests)

ARCH = "mixtral-8x7b"
MODEL_PAR = 2
PROMPT_LEN, GEN, SLOTS, N_REQ = 32, 8, 4, 12
PREFILL_CHUNK = 16
KV_BLOCK = 8
# req/s; 0 = closed batch, 5 ~ inter-arrival on the order of the service
# time (true open-loop interleaving), 50 = overload (arrivals finish in
# ~0.24s, so slot packing converges back to the closed-batch schedule)
RATES = [0.0, 5.0, 50.0]
SKEWS = [0.0, 0.9]
POLICIES = ["harmoeny", "round_robin"]


def build_engine(skew: float, policy: str, skew_seed: int, *,
                 slots: int = SLOTS, paged: bool = True,
                 num_kv_blocks: int = 0, prefix_sharing: bool = False,
                 gen: int = GEN, prompt_len: int = PROMPT_LEN,
                 speculative_k: int = 0, q_tokens: int = 0,
                 replica_slots: int = 0, rebalance_interval: int = 0,
                 resident_experts: int = 0,
                 prefetch_policy: str = "predictive",
                 placement=None):
    cfg = get_config(ARCH).reduced()
    moe = dataclasses.replace(cfg.moe, policy=policy)
    if skew > 0:
        moe = dataclasses.replace(moe, router_skew=skew)
    if q_tokens:
        moe = dataclasses.replace(moe, q_tokens=q_tokens)
    if replica_slots:
        moe = dataclasses.replace(moe, num_replica_slots=replica_slots)
    if placement is not None:
        moe = dataclasses.replace(moe, placement=tuple(int(e)
                                                       for e in placement))
    cfg = cfg.replace(moe=moe)
    mesh = make_host_mesh(data=1, model=MODEL_PAR)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=PROMPT_LEN),
                        batch=slots, seq_len=PROMPT_LEN,
                        mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params,
        engine_config_for(cfg, max_slots=slots, prompt_len=prompt_len,
                          max_new_tokens=gen, prefill_chunk=PREFILL_CHUNK,
                          skew_seed=skew_seed, paged=paged,
                          kv_block_size=KV_BLOCK,
                          num_kv_blocks=num_kv_blocks,
                          prefix_sharing=prefix_sharing,
                          speculative_k=speculative_k,
                          replica_slots=replica_slots,
                          rebalance_interval=rebalance_interval,
                          resident_experts=resident_experts,
                          prefetch_policy=prefetch_policy),
        mesh=mesh)
    engine.warmup()
    return cfg, engine


def _cell(rep, **extra):
    moe = rep.get("moe", {})
    return {
        **extra,
        "n_requests": rep["n_requests"],
        "ttft_p50_ms": rep["ttft"]["p50"] * 1e3,
        "ttft_p99_ms": rep["ttft"]["p99"] * 1e3,
        "tpot_p50_ms": rep["tpot"]["p50"] * 1e3,
        "tpot_p99_ms": rep["tpot"]["p99"] * 1e3,
        "e2e_p50_ms": rep["e2e"]["p50"] * 1e3,
        "tok_s": rep["throughput_tok_s"],
        "mean_occupancy": rep["mean_occupancy"],
        "max_concurrency": rep["max_occupancy"],
        "kv_utilization": rep.get("kv_utilization"),
        "prefix_hit_rate": rep.get("prefix_hit_rate"),
        "cow_copies": rep.get("cow_copies", 0),
        "evictions": rep.get("evictions", 0),
        "preemptions": rep["preemptions"],
        "decode_steps": rep["decode_steps"],
        "prefill_chunks": rep["prefill_chunks"],
        "recompiled_after_warmup": rep.get("recompiled_after_warmup"),
        "moved_units": moe.get("prefill/moved_units", 0.0),
        "drops": (moe.get("prefill/send_drops", 0.0)
                  + moe.get("prefill/dest_drops", 0.0)),
        "max_load_before": moe.get("prefill/max_load_before", 0.0),
        "max_load_after": moe.get("prefill/max_load_after", 0.0),
    }


def sweep():
    results = []
    for skew in SKEWS:
        for policy in POLICIES:
            cfg, engine = build_engine(skew, policy, skew_seed=1)
            for rate in RATES:
                engine.reset_metrics()
                reqs = poisson_requests(
                    N_REQ, rate=rate, vocab_size=cfg.vocab_size,
                    prompt_len=PROMPT_LEN, max_new_tokens=GEN, seed=0)
                rep = engine.run(reqs)
                cell = _cell(rep, rate=rate, skew=skew, policy=policy)
                results.append(cell)
                print(f"[bench] skew={skew} policy={policy:11s} "
                      f"rate={rate:5.0f} "
                      f"ttft_p50={cell['ttft_p50_ms']:8.1f}ms "
                      f"tpot_p50={cell['tpot_p50_ms']:6.2f}ms "
                      f"tok/s={cell['tok_s']:6.1f} "
                      f"kv_util={cell['kv_utilization']:.2f}")
    return results


def capacity_compare():
    """Slab vs paged at the same KV token budget, mixed prompt lengths.

    The slab pool reserves ``max_seq_len`` per slot, capping concurrency at
    SLOTS; the paged pool spends the identical token budget block-by-block
    and decodes more requests at once.
    """
    cells = []
    n_req = 16
    # slab budget: SLOTS x max_seq_len tokens per layer; the paged pool
    # spends one block of it on the reserved null block, so its USABLE
    # budget is one block smaller — physical memory is truly equal
    cfg, slab = build_engine(0.9, "harmoeny", skew_seed=1, paged=False)
    budget = SLOTS * slab.ecfg.max_seq_len
    _, paged = build_engine(0.9, "harmoeny", skew_seed=1, slots=2 * SLOTS,
                            paged=True,
                            num_kv_blocks=budget // KV_BLOCK - 1)
    for rate in (0.0, 50.0):
        for name, engine in (("slab", slab), ("paged", paged)):
            engine.reset_metrics()
            reqs = poisson_requests(
                n_req, rate=rate, vocab_size=cfg.vocab_size,
                prompt_len=PROMPT_LEN, max_new_tokens=GEN, seed=3,
                prompt_len_range=(8, PROMPT_LEN))
            rep = engine.run(reqs)
            cell = _cell(rep, pool=name, rate=rate, skew=0.9,
                         policy="harmoeny",
                         kv_budget_tokens=budget,
                         slots=engine.ecfg.max_slots)
            cells.append(cell)
            print(f"[bench] capacity pool={name:5s} rate={rate:5.0f} "
                  f"max_conc={cell['max_concurrency']} "
                  f"mean_occ={cell['mean_occupancy']:.2f} "
                  f"decode_steps={cell['decode_steps']} "
                  f"tok/s={cell['tok_s']:6.1f}")
    by = {(c["pool"], c["rate"]): c for c in cells}
    gains = {f"rate_{int(r)}":
             by[("paged", r)]["max_concurrency"]
             - by[("slab", r)]["max_concurrency"] for r in (0.0, 50.0)}
    more = all(g > 0 for g in gains.values())
    print(f"[bench] paged concurrency gain at equal memory: {gains} "
          f"(strictly more: {more})")
    return cells, gains, more


def prefix_compare():
    """Shared-prefix traces: prefix sharing on vs off at the same block
    budget.

    Two workloads — a common 24-token system prompt with per-request tails,
    and identical full prompts (the full-hit copy-on-write path).  Each
    cell runs on a fresh engine, then one warming request puts the shared
    prefix in residence before the measured window — the steady-state
    regime prefix caching targets (a system prompt is resident from the
    first seconds of serving; a cold closed batch admits every slot before
    anything is committed and mostly measures scheduler noise).  Sharing
    then serves the common prefix out of the block cache, so every request
    prefills only its tail: fewer prefill chunks, lower TTFT.  The
    no-sharing engine gets the identical warming run (state-symmetric, but
    it has no cache to warm).
    """
    budget = SLOTS * 8          # blocks; same physical pool either way
    cells = []
    for workload, prefix_len in (("system_prompt", 24), ("identical", 32)):
        for share in (False, True):
            cfg, engine = build_engine(0.9, "harmoeny", skew_seed=1,
                                       paged=True, num_kv_blocks=budget,
                                       prefix_sharing=share)
            reqs = poisson_requests(
                N_REQ, rate=0.0, vocab_size=cfg.vocab_size,
                prompt_len=PROMPT_LEN, max_new_tokens=GEN, seed=4,
                shared_prefix_len=prefix_len)
            # same seed => the warming request carries the same shared
            # prefix the measured batch does
            warm = poisson_requests(
                1, rate=0.0, vocab_size=cfg.vocab_size,
                prompt_len=PROMPT_LEN, max_new_tokens=GEN, seed=4,
                shared_prefix_len=prefix_len)
            engine.run(warm)
            engine.reset_metrics()
            rep = engine.run(reqs)
            cell = _cell(rep, workload=workload, sharing=share,
                         shared_prefix_len=prefix_len, skew=0.9,
                         policy="harmoeny", kv_budget_blocks=budget)
            cells.append(cell)
            print(f"[bench] prefix workload={workload:13s} "
                  f"sharing={str(share):5s} "
                  f"ttft_p50={cell['ttft_p50_ms']:8.1f}ms "
                  f"prefill_chunks={cell['prefill_chunks']:3d} "
                  f"hit={cell['prefix_hit_rate']} "
                  f"cow={cell['cow_copies']}")
    by = {(c["workload"], c["sharing"]): c for c in cells}
    reductions = {
        w: by[(w, False)]["ttft_p50_ms"] - by[(w, True)]["ttft_p50_ms"]
        for w in ("system_prompt", "identical")}
    faster = all(r > 0 for r in reductions.values())
    print(f"[bench] prefix sharing TTFT p50 reduction (ms): {reductions} "
          f"(all faster: {faster})")
    return cells, reductions, faster


def speculative_compare():
    """Self-drafting speculative decode on a repetitive-text workload.

    Prompts are a tiled 4-token motif, so the greedy continuation loops
    and the n-gram prompt-lookup proposer keeps finding its suffix — the
    regime speculative decoding targets (code, quoting, templated
    answers).  Each cell decodes the same closed batch with
    ``speculative_k`` in {0, 2, 4}: k = 0 is the plain-decode baseline
    (exactly one slot-step per committed token); k > 0 must report
    acceptance > 0 and per-slot decode steps per committed token < 1.0,
    with greedy streams token-identical across cells (asserted by
    ``tests/test_serve_speculative.py``; here the committed token COUNTS
    are cross-checked).  A contrast cell decodes incompressible random
    prompts at k = 4 — acceptance collapses and steps/token returns to
    ~1.0, the honest bound on when speculation pays off.
    """
    from repro.serve import Request

    # short prompts + a long decode phase (the speculative regime), sized
    # so the padded pool (+k) still fits the reduced model's 64-token
    # sliding window: round_up(16 + 30, 16) + 4 -> 52, block-rounded 56
    n_req, plen, gen = 8, 16, 30
    cells = []

    def requests(workload):
        rng = np.random.default_rng(7)
        reqs = []
        for i in range(n_req):
            if workload == "repetitive":
                motif = rng.integers(0, 64, (4,)).astype(np.int32)
                toks = np.tile(motif, -(-plen // 4))[:plen]
            else:               # incompressible: i.i.d. random prompt
                toks = rng.integers(0, 64, (plen,)).astype(np.int32)
            reqs.append(Request(rid=i, tokens=toks, max_new_tokens=gen))
        return reqs

    for workload, ks in (("repetitive", (0, 2, 4)), ("random", (4,))):
        for k in ks:
            cfg, engine = build_engine(0.9, "harmoeny", skew_seed=1,
                                       gen=gen, prompt_len=plen,
                                       speculative_k=k)
            rep = engine.run(requests(workload))
            sp = rep.get("speculative", {})
            cell = _cell(rep, workload=workload, speculative_k=k,
                         skew=0.9, policy="harmoeny",
                         total_new_tokens=rep["total_new_tokens"],
                         acceptance_rate=sp.get("acceptance_rate"),
                         drafted=sp.get("drafted", 0),
                         accepted=sp.get("accepted", 0),
                         spec_tokens_per_step=sp.get("tokens_per_step"),
                         steps_per_committed_token=sp.get(
                             "steps_per_committed_token"))
            cells.append(cell)
            print(f"[bench] speculative workload={workload:10s} k={k} "
                  f"acc={cell['acceptance_rate']} "
                  f"steps/token={cell['steps_per_committed_token']} "
                  f"decode_steps={cell['decode_steps']:3d} "
                  f"tpot_p50={cell['tpot_p50_ms']:6.2f}ms")
    by = {(c["workload"], c["speculative_k"]): c for c in cells}
    # same workload => same greedy stream => identical committed counts
    tokens_equal = len({by[("repetitive", k)]["total_new_tokens"]
                        for k in (0, 2, 4)}) == 1
    steps_per_token = {
        f"k{k}": by[("repetitive", k)]["steps_per_committed_token"]
        for k in (2, 4)}
    wins = all(v is not None and v < 1.0 for v in steps_per_token.values())
    print(f"[bench] speculative steps/committed token (repetitive): "
          f"{steps_per_token} (< 1.0: {wins}; token counts equal across "
          f"k: {tokens_equal})")
    return cells, steps_per_token, wins, tokens_equal


def skew_compare():
    """Serving under heavy skew: harmoeny + hot-expert replication vs the
    baselines, at an equal per-rank capacity budget (capacity_factor 1.25).

    Two instruments per policy (the split the simulator docstring
    mandates — wall-clock on CPU-emulated devices cannot see imbalance,
    because every rank executes the same static-shape program):

    * **engine cells** — the real serving engine at router_skew 0.9:
      wall TTFT/tok_s, the measured per-rank load vectors (max/mean
      ratio, straggler-wait GPU-idle proxy), scheduler drop counts, and
      replica swap counts.  Greedy streams are token-identical across
      policies (asserted in tests), so every cell decodes the same
      tokens.
    * **modeled cells** — the calibrated v5e time model over a live
      drifting stream at paper scale (G=8, E=64, a large fused batch per
      step, movement granularity at the Eq. 4 q-threshold): phase 1
      draws from the 4-hot-expert profile ``static_opt`` was placed for,
      phase 2 drifts to one scorching previously-cold expert.  Each
      step's REAL schedule (core/scheduler.py, same code the engine
      jits) is capacity-clamped at 1.25x the mean per-rank load (the
      dispatch drop path: an imbalanced policy drops the excess AND
      still waits on its clamped hottest rank) and costed with
      ``simulate_layer``; throughput counts delivered units only.  The
      replication cell feeds the live ``expert_load`` stream through the
      same ``ExpertRebalancer`` the engine uses and credits
      replica-resident experts as fetch-free.

    Headline: modeled delivered throughput of harmoeny + replication
    beats the next-best baseline under skew >= 0.8, while its
    capacity-budget overflow (the dispatch drop proxy) stays ~0.
    """
    engine_cells = skew_engine_cells()
    modeled = skew_modeled_cells()

    by = {c["policy"]: c for c in modeled}
    ours = by["harmoeny+replication"]
    best_baseline = max((c for c in modeled
                         if c["policy"] != "harmoeny+replication"),
                        key=lambda c: c["tok_s_modeled"])
    headline = {
        "ours_tok_s": ours["tok_s_modeled"],
        "next_best_policy": best_baseline["policy"],
        "next_best_tok_s": best_baseline["tok_s_modeled"],
        "speedup_vs_next_best":
            ours["tok_s_modeled"] / best_baseline["tok_s_modeled"],
        "beats_next_best":
            ours["tok_s_modeled"] > best_baseline["tok_s_modeled"],
        "ours_overflow_units": ours["overflow_units_total"],
        "ours_overflow_steady_units": ours["overflow_units_steady"],
        "engine_drops_zero": all(
            c["send_drops"] + c["dest_drops"] == 0 for c in engine_cells
            if c["policy"] != "even_split"),
    }
    print(f"[bench] skew headline: ours={headline['ours_tok_s']:.0f} tok/s "
          f"vs {headline['next_best_policy']}="
          f"{headline['next_best_tok_s']:.0f} "
          f"({headline['speedup_vs_next_best']:.2f}x, beats: "
          f"{headline['beats_next_best']}); overflow="
          f"{headline['ours_overflow_units']:.0f} "
          f"(steady={headline['ours_overflow_steady_units']:.0f})")
    return {"engine_cells": engine_cells, "modeled_cells": modeled,
            "headline": headline}


SKEW = 0.9
CF = 1.25


def skew_engine_cells():
    """Real-engine skew cells (see ``skew_compare``)."""
    from repro.core.topology import static_opt_placement

    engine_cells = []
    prof = None
    for name in ("harmoeny+replication", "harmoeny", "round_robin",
                 "even_split", "static_opt"):
        policy = name.split("+")[0]
        kw = {}
        if name == "harmoeny+replication":
            kw = dict(replica_slots=1, rebalance_interval=4)
        if policy == "static_opt":
            # profile-then-place against the synthetic skew distribution
            cfg0 = get_config(ARCH).reduced()
            E, H = cfg0.moe.num_experts, cfg0.moe.router_skew_experts
            prof = np.full((E,), (1.0 - SKEW) / max(E - H, 1))
            prof[:H] = SKEW / max(H, 1)
            kw = dict(placement=static_opt_placement(
                (prof * 10_000).astype(np.int64), MODEL_PAR))
        cfg, engine = build_engine(SKEW, policy, skew_seed=1, q_tokens=2,
                                   **kw)
        reqs = poisson_requests(N_REQ, rate=0.0, vocab_size=cfg.vocab_size,
                                prompt_len=PROMPT_LEN, max_new_tokens=GEN,
                                seed=5)
        rep = engine.run(reqs)
        lb = rep.get("load_balance", {}).get("decode", {})
        cell = {
            "policy": name, "skew": SKEW, "capacity_factor": CF,
            "ttft_p50_ms": rep["ttft"]["p50"] * 1e3,
            "tok_s_wall": rep["throughput_tok_s"],
            "max_mean_ratio": lb.get("max_mean_ratio"),
            "straggler_wait_units": lb.get("straggler_wait_units"),
            "send_drops": lb.get("send_drops_total", 0.0),
            "dest_drops": lb.get("dest_drops_total", 0.0),
            "replica_swaps": rep["engine"].get("replica_swaps", 0),
            "hot_experts": rep["engine"].get("hot_experts", []),
            "recompiled_after_warmup": rep.get("recompiled_after_warmup"),
        }
        engine_cells.append(cell)
        print(f"[bench] skew-engine {name:21s} "
              f"ttft_p50={cell['ttft_p50_ms']:7.1f}ms "
              f"tok/s={cell['tok_s_wall']:6.1f} "
              f"ratio={cell['max_mean_ratio']:.2f} "
              f"straggler={cell['straggler_wait_units']:.1f} "
              f"drops={cell['send_drops']:.0f}/{cell['dest_drops']:.0f} "
              f"swaps={cell['replica_swaps']}")
    return engine_cells


def skew_modeled_cells():
    """v5e-modeled drifting-stream skew cells (see ``skew_compare``)."""
    import jax.numpy as jnp
    from repro.core.scheduler import schedule
    from repro.core.simulator import SimCosts, simulate_layer
    from repro.core.topology import make_topology, static_opt_placement
    from repro.serve.rebalance import ExpertRebalancer

    # ---------------- modeled cells (drifting stream, v5e time model) --
    # Paper-scale operating point: U token units per step (a large fused
    # decode/verify batch over many concurrent requests) and movement
    # granularity Q set to the Eq. 4 q-threshold under the sim's own cost
    # model — the smallest chunk whose compute masks one expert fetch
    # (fetch_s / comp_per_unit_s).  Below this scale redistribution can
    # never pay (fetch dominates), which is precisely the paper's point.
    G, E, K_SLOTS, R_SLOTS = 8, 64, 4, 4
    U, T = 65536, 120
    N_HOT = 4
    costs = SimCosts()
    comp_unit_s = costs.unit_flops / (costs.hw.peak_flops * costs.mfu)
    fetch_s = costs.expert_bytes * costs.fetch_penalty / costs.hw.ici_bw
    Q = int(np.ceil(fetch_s / comp_unit_s))
    rng = np.random.default_rng(11)

    def probs(phase):
        p = np.full((E,), 0.0)
        if phase == 0:                  # matches static_opt's profile
            p[:] = (1.0 - SKEW) / (E - N_HOT)
            p[:N_HOT] = SKEW / N_HOT
        else:                           # drift: one scorching cold expert
            p[:] = (1.0 - SKEW) / (E - 1)
            p[E // 2] = SKEW
        return p

    place = static_opt_placement(
        (probs(0) * 10_000).astype(np.int64), G)
    topos = {"static_opt": make_topology(G, E, placement=place)}
    base_topo = make_topology(G, E)
    cap = CF * U / G
    modeled = []
    for name in ("harmoeny+replication", "harmoeny", "round_robin",
                 "even_split", "static_opt"):
        policy = name.split("+")[0]
        topo = topos.get(name, base_topo)
        rb = (ExpertRebalancer(topo, R_SLOTS)
              if name == "harmoeny+replication" else None)
        extra = None
        layer_s = np.zeros(2)
        units = np.zeros(2)
        idle = []
        overflow = 0.0
        overflow_steady = 0.0
        # adaptation windows: the EMA rebalancer cannot react before its
        # next proposal, so overflow inside 2 proposal periods after t=0
        # and after the phase flip is inherent drift lag, not steady-state
        # behaviour — both numbers are reported
        P = 10
        warmup = set(range(0, 2 * P)) | set(range(T // 2, T // 2 + 2 * P))
        ratios = []
        for t in range(T):
            phase = 0 if t < T // 2 else 1
            counts = rng.multinomial(U // G, probs(phase), size=G)
            S, diag = schedule(jnp.asarray(counts, jnp.int32), topo,
                               policy=policy, q=Q, c_pair=10 ** 6,
                               num_foreign_slots=K_SLOTS,
                               extra_local=(None if extra is None
                                            else jnp.asarray(extra)))
            # Equal capacity budget: every destination computes at most
            # ``cap`` units; the rest is dropped at dispatch (the engine's
            # dest_drops path).  Throughput counts delivered units only,
            # and layer time is costed on the clamped schedule — an
            # imbalanced policy both drops tokens AND still waits on its
            # (capacity-clamped) hottest rank.
            S_np = np.asarray(S, np.float64)
            load = S_np.sum(axis=(0, 1))
            over = float(np.maximum(load - cap, 0.0).sum())
            overflow += over
            if t not in warmup:
                overflow_steady += over
            scale = np.where(load > cap, cap / np.maximum(load, 1e-9), 1.0)
            S_del = S_np * scale[None, None, :]
            sim = simulate_layer(S_del, topo, costs,
                                 sched_iters=int(diag.iters),
                                 drops=over, extra_local=extra)
            layer_s[phase] += sim["layer_s"]
            units[phase] += float(S_del.sum())
            idle.append(sim["idle_frac_mean"])
            ratios.append(float(load.max() / max(load.mean(), 1e-9)))
            if rb is not None:
                rb.observe(S_np.sum(axis=(0, 2)))
                if (t + 1) % P == 0:
                    dec = rb.propose()
                    if dec.changed:
                        ids = dec.replica_ids
                        extra = np.zeros((G, topo.padded_experts), bool)
                        for g in range(G):
                            for e in ids[g]:
                                if e >= 0:
                                    extra[g, e] = True
        cell = {
            "policy": name, "skew": SKEW, "capacity_factor": CF,
            "ranks": G, "experts": E, "units_per_step": U,
            "q_units": Q,
            "delivered_frac": float(units.sum() / (U * T)),
            "tok_s_modeled": float(units.sum() / layer_s.sum()),
            "tok_s_modeled_phase1": float(units[0] / layer_s[0]),
            "tok_s_modeled_phase2": float(units[1] / layer_s[1]),
            "layer_us_mean": float(layer_s.sum() / T * 1e6),
            "idle_frac_mean": float(np.mean(idle)),
            "imbalance_mean": float(np.mean(ratios)),
            "overflow_units_total": overflow,
            "overflow_units_steady": overflow_steady,
        }
        modeled.append(cell)
        print(f"[bench] skew-model  {name:21s} "
              f"tok/s={cell['tok_s_modeled']:12.0f} "
              f"(p1 {cell['tok_s_modeled_phase1']:12.0f} / "
              f"p2 {cell['tok_s_modeled_phase2']:12.0f}) "
              f"idle={cell['idle_frac_mean']:.2f} "
              f"imb={cell['imbalance_mean']:.2f} "
              f"overflow={cell['overflow_units_total']:.0f}"
              f"/steady {cell['overflow_units_steady']:.0f}")
    return modeled


def residency_compare():
    """Tiered expert residency: host↔HBM streaming at a bounded HBM budget.

    Two instruments, same split as ``skew_compare``:

    * **engine cells** — the real serving engine under router skew with a
      tight working-set budget (``resident_experts`` = half the expert
      rows, W = epr/2 per rank) across the three prefetch policies plus
      the fully-resident baseline.  Greedy streams are token-identical
      across budgets by construction (device params stay authoritative —
      asserted in tests); the cells carry the live ``residency`` report:
      hit rate, swap/prefetch counts, staged bytes, and the
      TierCostModel-priced stall seconds of the emulated PCIe tier.

    * **modeled cells** — paper-scale (G=8, E=64) layer costing over a
      drifting two-MoE-layer stream.  Each step schedules with the REAL
      HarMoEny scheduler under the ``non_local`` demotion mask derived
      from the previous step's residency table (double-buffered, exactly
      the engine's discipline), and is costed with ``simulate_layer``;
      host-tier stalls are charged from the ``ExpertResidencyManager``
      replay itself — the only party that knows which misses the
      predictive policy staged *ahead* of first touch (hidden behind the
      previous layer's compute window) versus paid for on demand.  All
      demoted pairs are passed as ``hidden_stages`` so the simulator does
      not double-charge the tier on top of the manager's accounting.

      The stream: layer 0 routes to one stable expert per rank; layer 1
      routes to a second expert per rank that *drifts* to a cold third
      mid-run.  ``predictive`` prefetches the incoming expert during
      layer 0's window of the very first post-drift step (the per-layer
      EMA folds the step's own loads before the replay), ``on_demand``
      stalls once per rank on first touch, and ``none`` stalls on every
      single post-drift use of the never-admitted expert — whose demotion
      also reroutes its tokens as fetch-paying foreign work in the
      schedule.

    Headline: at half the HBM footprint, predictive stalls strictly less
    than on_demand and recovers ~all of the fully-resident modeled
    throughput, while ``none`` (no streaming) pays a persistent tier
    penalty.
    """
    engine_cells = residency_engine_cells()
    modeled = residency_modeled_cells()

    by = {c["cell"]: c for c in modeled}
    pred, odem = by["predictive"], by["on_demand"]
    headline = {
        "budget_experts": pred["resident_experts"],
        "footprint_frac": pred["footprint_frac"],
        "predictive_stall_s": pred["host_stall_s"],
        "on_demand_stall_s": odem["host_stall_s"],
        "predictive_beats_on_demand_on_stall":
            pred["host_stall_s"] < odem["host_stall_s"],
        "recovered_throughput_frac":
            pred["tok_s_modeled"] / by["fully_resident"]["tok_s_modeled"],
        "none_throughput_frac":
            by["none"]["tok_s_modeled"]
            / by["fully_resident"]["tok_s_modeled"],
        "engine_predictive_hit_rate": next(
            (c["hit_rate"] for c in engine_cells
             if c["cell"] == "predictive"), None),
    }
    print(f"[bench] residency headline: budget={headline['budget_experts']} "
          f"({headline['footprint_frac']:.0%} footprint) "
          f"stall pred={headline['predictive_stall_s'] * 1e3:.2f}ms vs "
          f"odem={headline['on_demand_stall_s'] * 1e3:.2f}ms "
          f"(beats: {headline['predictive_beats_on_demand_on_stall']}); "
          f"recovered={headline['recovered_throughput_frac']:.3f} "
          f"none={headline['none_throughput_frac']:.3f}")
    return {"engine_cells": engine_cells, "modeled_cells": modeled,
            "headline": headline}


def residency_engine_cells():
    """Real-engine residency cells (see ``residency_compare``)."""
    cfg0 = get_config(ARCH).reduced()
    E = cfg0.moe.num_experts                      # pod expert rows (epr*G)
    cells = []
    for name, budget, policy in (
            ("fully_resident", E, "predictive"),
            ("predictive", E // 2, "predictive"),
            ("on_demand", E // 2, "on_demand"),
            ("none", E // 2, "none")):
        cfg, engine = build_engine(SKEW, "harmoeny", skew_seed=1,
                                   resident_experts=budget,
                                   prefetch_policy=policy)
        reqs = poisson_requests(N_REQ, rate=0.0, vocab_size=cfg.vocab_size,
                                prompt_len=PROMPT_LEN, max_new_tokens=GEN,
                                seed=5)
        rep = engine.run(reqs)
        res = rep["residency"]
        cell = {
            "cell": name, "policy": policy, "skew": SKEW,
            "resident_experts": budget,
            "footprint_frac": budget / E,
            "tok_s_wall": rep["throughput_tok_s"],
            "hit_rate": res["hit_rate"],
            "swaps": res["swaps"],
            "prefetches": res["prefetches"],
            "stall_s": res["stall_units"],
            "bytes_staged": res["bytes_staged"],
            "residency_stages": rep["engine"]["residency_stages"],
            "recompiled_after_warmup": rep.get("recompiled_after_warmup"),
        }
        cells.append(cell)
        print(f"[bench] residency-engine {name:14s} budget={budget} "
              f"hit={cell['hit_rate']:.3f} swaps={cell['swaps']:4d} "
              f"prefetch={cell['prefetches']:4d} "
              f"stall={cell['stall_s'] * 1e3:7.2f}ms "
              f"staged={cell['bytes_staged'] / 2 ** 20:7.1f}MB "
              f"tok/s={cell['tok_s_wall']:6.1f}")
    return cells


def residency_modeled_cells():
    """v5e-modeled drifting-stream residency cells (see
    ``residency_compare``)."""
    import gc

    import jax
    import jax.numpy as jnp

    # By this point every earlier section has compiled its own engines and
    # the process carries thousands of cached CPU executables; the LLVM JIT
    # can hit mmap exhaustion (ENOMEM → segfault) on the next burst of
    # compilations. Drop the compile caches before the modeled loop — the
    # remaining sections build fresh engines and recompile regardless.
    jax.clear_caches()
    gc.collect()
    from repro.core.scheduler import schedule
    from repro.core.simulator import SimCosts, simulate_layer
    from repro.core.topology import local_slot_of, make_topology
    from repro.serve.residency import ExpertResidencyManager, TierCostModel

    G, E, L = 8, 64, 2
    U, T = 65536, 80
    K_SLOTS = 4
    W = 4                                # budget: half of epr=8 per rank
    costs = SimCosts()
    comp_unit_s = costs.unit_flops / (costs.hw.peak_flops * costs.mfu)
    fetch_s = costs.expert_bytes * costs.fetch_penalty / costs.hw.ici_bw
    Q = int(np.ceil(fetch_s / comp_unit_s))
    topo = make_topology(G, E)
    Ep = topo.padded_experts
    lsl = local_slot_of(topo)

    # per-layer active experts, ONE local slot per rank per layer: layer 0
    # stays on slot 0; layer 1 uses slot 1 and drifts to the cold slot 5
    # at T/2 (outside the seeded working set {slots 0..W-1}, so only
    # streaming can admit it)
    def active_slots(layer, phase):
        return {(0, 0): 0, (0, 1): 0, (1, 0): 1, (1, 1): 5}[(layer, phase)]

    def layer_counts(rng, layer, phase):
        j = active_slots(layer, phase)
        p = np.zeros(Ep)
        for g in range(G):
            p[int(topo.slot_map[g, j])] = 1.0 / G
        return rng.multinomial(U // G, p, size=G)            # [G, Ep]

    cells = []
    for name, budget, policy in (
            ("fully_resident", G * topo.experts_per_rank, "predictive"),
            ("predictive", G * W, "predictive"),
            ("on_demand", G * W, "on_demand"),
            ("none", G * W, "none")):
        mgr = ExpertResidencyManager(
            topo, budget, policy=policy,
            cost=TierCostModel(expert_bytes=costs.expert_bytes,
                               pcie_bw=costs.host_bw))
        rng = np.random.default_rng(13)      # same stream in every cell
        compute_s = 0.0
        stall_s = 0.0
        units = 0.0
        for t in range(T):
            phase = 0 if t < T // 2 else 1
            # double-buffered: step t schedules under the table published
            # at the end of step t-1, exactly like the engine
            ids = mgr._last_ids
            res = np.zeros((G, Ep), bool)
            for g in range(G):
                for e in ids[g]:
                    if e >= 0:
                        res[g, int(e)] = True
            non_local = (lsl >= 0) & ~res
            loads = np.zeros((L, Ep))
            for layer in range(L):
                counts = layer_counts(rng, layer, phase)
                loads[layer] = counts.sum(axis=0)
                S, diag = schedule(jnp.asarray(counts, jnp.int32), topo,
                                   policy="harmoeny", q=Q, c_pair=10 ** 6,
                                   num_foreign_slots=K_SLOTS,
                                   non_local=jnp.asarray(non_local))
                S_np = np.asarray(S, np.float64)
                sim = simulate_layer(S_np, topo, costs,
                                     sched_iters=int(diag.iters),
                                     non_local=non_local,
                                     hidden_stages=non_local)
                compute_s += sim["layer_s"]
                units += float(S_np.sum())
            dec = mgr.step(loads)
            stall_s += dec.stall_units
        w = mgr.counters()
        total_s = compute_s + stall_s
        cell = {
            "cell": name, "policy": policy,
            "ranks": G, "experts": E, "units_per_step": U,
            "moe_layers": L, "steps": T, "q_units": Q,
            "resident_experts": budget,
            "footprint_frac": budget / (G * topo.experts_per_rank),
            "tok_s_modeled": float(units / total_s),
            "layer_us_mean": float(compute_s / (T * L) * 1e6),
            "host_stall_s": float(stall_s),
            "stall_frac": float(stall_s / total_s),
            "hit_rate": w["hit_rate"],
            "swaps": w["swaps"],
            "prefetches": w["prefetches"],
            "bytes_staged": w["bytes_staged"],
        }
        cells.append(cell)
        print(f"[bench] residency-model  {name:14s} budget={budget:2d} "
              f"({cell['footprint_frac']:.0%}) "
              f"tok/s={cell['tok_s_modeled']:12.0f} "
              f"stall={cell['host_stall_s'] * 1e3:8.2f}ms "
              f"({cell['stall_frac']:.1%}) hit={cell['hit_rate']:.3f} "
              f"swaps={cell['swaps']:3d} prefetch={cell['prefetches']:3d}")
    return cells


def decode_attention_microbench():
    """Reference gather vs fused kernel, active length swept against L_max.

    The reference (``paged_decode_attention``) gathers each row's full
    ``[L_max, Hkv, hd]`` logical K/V view and repeats KV heads per q head
    every decode step, so its memory traffic is constant in the actual
    sequence length; the fused kernel walks the block table inside the
    kernel and reads only ``ceil(active / block_size)`` blocks per row.
    Off-TPU the kernel runs in interpret mode, so its absolute wall time
    is not meaningful there — the theoretical bytes columns (and the
    reference timings) carry the comparison; on TPU both time columns are
    real.  Every cell also cross-checks parity (``max_abs_err``).
    """
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.models.attention import paged_decode_attention

    B, Hkv, rep, hd, bs = 4, 2, 4, 64, 16
    l_max = 512
    n_logical = l_max // bs
    num_blocks = 1 + B * n_logical
    P = num_blocks * bs
    key = jax.random.PRNGKey(0)
    k_pool = jax.random.normal(jax.random.fold_in(key, 1), (1, P, Hkv, hd))
    v_pool = jax.random.normal(jax.random.fold_in(key, 2), (1, P, Hkv, hd))
    q = jax.random.normal(jax.random.fold_in(key, 3), (B, 1, Hkv * rep, hd))
    interpret = jax.default_backend() != "tpu"
    ref_fn = jax.jit(functools.partial(paged_decode_attention,
                                       block_size=bs))
    fused_fn = jax.jit(functools.partial(paged_attention, block_size=bs,
                                         interpret=interpret))
    perm = np.random.default_rng(0).permutation(np.arange(1, num_blocks))

    def timed(fn, bt, cl, iters):
        out = fn(q, k_pool, v_pool, bt, cl)
        jax.block_until_ready(out)                    # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(q, k_pool, v_pool, bt, cl)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e3, np.asarray(
            out, np.float32)

    cells = []
    for active in (32, 128, 512):
        bt = np.zeros((B, n_logical), np.int32)
        i = 0
        for b in range(B):                            # rest stay null
            nv = active // bs
            bt[b, :nv] = perm[i:i + nv]
            i += nv
        btj = jnp.asarray(bt)
        cl = jnp.full((B,), active, jnp.int32)
        ref_ms, ref_out = timed(ref_fn, btj, cl, iters=30)
        fused_ms, fused_out = timed(fused_fn, btj, cl, iters=5)
        leaf_bytes = 2 * Hkv * hd * 4                 # K+V, f32
        cell = {
            "active_len": active, "l_max": l_max,
            "gather_ref_ms": ref_ms, "fused_ms": fused_ms,
            "gather_ref_bytes": B * l_max * leaf_bytes,
            "fused_bytes": B * active * leaf_bytes,
            "max_abs_err": float(np.abs(ref_out - fused_out).max()),
        }
        cells.append(cell)
        print(f"[bench] decode_attn active={active:4d}/{l_max} "
              f"gather_ref={ref_ms:7.3f}ms ({cell['gather_ref_bytes']:>9d} B)"
              f"  fused={fused_ms:7.3f}ms ({cell['fused_bytes']:>9d} B)  "
              f"err={cell['max_abs_err']:.2e}")
    return {
        "shape": {"batch": B, "kv_heads": Hkv, "gqa_rep": rep, "head_dim": hd,
                  "block_size": bs, "l_max": l_max},
        "fused_interpret_mode": interpret,
        "cells": cells,
    }


def _phase_engine(*, fused: bool, prompt_len: int, gen: int, chunk: int,
                  prefix_sharing: bool = False, speculative_k: int = 0,
                  slots: int = 2, kv_block: int = KV_BLOCK):
    """Engine for the phase-breakdown cells: window disabled (long-context
    paged pools exceed the reduced arch's 64-token window), unified fused
    path (q-tiled prefill attention + paged decode/verify + grouped-GEMM
    MoE) on or off as one switch."""
    # window disabled for long paged pools; 2 layers keep the interpret-
    # mode (off-TPU) fused cells inside a sane wall budget — the fused /
    # gather contrast is per-layer, so the layer count cancels out
    cfg = get_config(ARCH).reduced().replace(sliding_window=0, num_layers=2)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, policy="harmoeny"))
    mesh = make_host_mesh(data=1, model=MODEL_PAR)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=min(512, prompt_len)),
                        batch=slots, seq_len=prompt_len,
                        mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params,
        engine_config_for(cfg, max_slots=slots, prompt_len=prompt_len,
                          max_new_tokens=gen, prefill_chunk=chunk,
                          skew_seed=1, paged=True, kv_block_size=kv_block,
                          prefix_sharing=prefix_sharing,
                          speculative_k=speculative_k,
                          fused_paged_attention=fused,
                          fused_moe_gmm=fused),
        mesh=mesh)
    engine.warmup()
    return cfg, engine


def phases_breakdown():
    """Per-phase tok/s + analytic KV bytes, fused vs gather (docstring top).

    Cell pairs (identical workload, greedy, token counts cross-checked):

    * ``long_prefill`` — 2048-token prompts sharing a 1024-token prefix,
      prefix sharing on: exercises prefill, prefix-tail resume, and plain
      decode.  The reference ``chunked_attention`` scans the whole
      [1, s_pad] slab every chunk; the fused q-tiled kernel's causal
      pruning stops at ``q_offset + chunk``, so its bytes grow with the
      filled prefix instead of the pool size.
    * ``spec_verify`` — short repetitive prompts, speculative k=4:
      exercises the [B, k+1] verify phase, where the reference gather
      materializes every row's full logical view per step.
    """
    from repro.core.qthreshold import V5E

    interpret = jax.default_backend() != "tpu"
    cells = []

    def run_cell(workload, *, fused, prompt_len, gen, chunk, sharing,
                 k, n_req, shared_prefix=0, kv_block=KV_BLOCK):
        cfg, engine = _phase_engine(fused=fused, prompt_len=prompt_len,
                                    gen=gen, chunk=chunk,
                                    prefix_sharing=sharing,
                                    speculative_k=k, kv_block=kv_block)
        if workload == "spec_verify":
            # tiled motif prompts so the n-gram proposer drafts well and
            # the verify phase commits multi-token windows
            from repro.serve import Request
            rng = np.random.default_rng(7)
            reqs = []
            for i in range(n_req):
                motif = rng.integers(0, 64, (4,)).astype(np.int32)
                reqs.append(Request(
                    rid=i, tokens=np.tile(motif, -(-prompt_len // 4))
                    [:prompt_len], max_new_tokens=gen))
        else:
            reqs = poisson_requests(
                n_req, rate=0.0, vocab_size=cfg.vocab_size,
                prompt_len=prompt_len, max_new_tokens=gen, seed=6,
                shared_prefix_len=shared_prefix)
        t0 = time.perf_counter()
        if sharing:
            # a cold first request populates the prefix cache INSIDE the
            # measured window: its chunks are the plain-prefill phase, the
            # same-seed followers resume off its cached prefix and land in
            # the prefix-tail phase
            warm = poisson_requests(
                1, rate=0.0, vocab_size=cfg.vocab_size,
                prompt_len=prompt_len, max_new_tokens=gen, seed=6,
                shared_prefix_len=shared_prefix)
            engine.run(warm)
        rep = engine.run(reqs)
        wall_s = time.perf_counter() - t0
        phases = {}
        for name, ph in rep.get("phases", {}).items():
            ph = dict(ph)
            # bytes-roofline model: phase time if KV traffic were the
            # bottleneck at v5e HBM bandwidth — the TPU-relevant contrast
            # when the wall columns run the kernel in interpret mode
            ph["modeled_roofline_tok_s"] = (
                ph["tokens"] / (ph["kv_bytes_touched"] / V5E.hbm_bw)
                if ph["kv_bytes_touched"] else None)
            phases[name] = ph
        cell = {
            "workload": workload, "fused": fused,
            "prompt_len": prompt_len, "gen": gen,
            "prefill_chunk": chunk, "speculative_k": k,
            "prefix_sharing": sharing, "n_requests": n_req,
            "total_new_tokens": rep["total_new_tokens"],
            "e2e_wall_s": wall_s,
            "e2e_tok_s_wall": rep["total_new_tokens"] / wall_s,
            "kv_bytes_total": sum(ph["kv_bytes_touched"]
                                  for ph in phases.values()),
            "phases": phases,
            "attention_dispatch": rep.get("attention_dispatch", {}),
            "attention_fallbacks": rep.get("attention_fallbacks", {}),
        }
        if fused:
            # acceptance: with use_pallas on, no hot phase may dispatch
            # the [B, L_max] logical gather or silently fall back
            assert cell["attention_fallbacks"] == {}, \
                f"silent fused fallbacks: {cell['attention_fallbacks']}"
            for branch, d in cell["attention_dispatch"].items():
                assert d["fused"], f"branch {branch} fell back: {d}"
        cells.append(cell)
        for name, ph in sorted(phases.items()):
            print(f"[bench] phases {workload:12s} fused={str(fused):5s} "
                  f"{name:11s} tok/s={ph['tokens_per_s']:9.1f} "
                  f"bytes/token={ph['kv_bytes_per_token']:10.0f} "
                  f"roofline={ph['modeled_roofline_tok_s'] or 0:12.0f}")
        return cell

    # long-context: 2048-token prompts; s_pad = 2048 + 256 (round-up) +
    # 256 (prefix-sharing chunk) = 2560 = 20 x 128-token slab tiles
    for fused in (False, True):
        # 64-token KV blocks: the default 8-token blocks make the
        # interpret-mode decode grid 8x deeper on the 2.5k-token pool
        # for no extra information
        run_cell("long_prefill", fused=fused, prompt_len=2048, gen=8,
                 chunk=256, sharing=True, k=0, n_req=2,
                 shared_prefix=1024, kv_block=64)
    for fused in (False, True):
        run_cell("spec_verify", fused=fused, prompt_len=16, gen=30,
                 chunk=16, sharing=False, k=4, n_req=4)

    by = {(c["workload"], c["fused"]): c for c in cells}
    summary = {}
    for w in ("long_prefill", "spec_verify"):
        g, f = by[(w, False)], by[(w, True)]
        assert g["total_new_tokens"] == f["total_new_tokens"], \
            "fused and gather cells decoded different streams"
        hot = "prefix_tail" if w == "long_prefill" else "verify"
        summary[w] = {
            "tokens_identical": True,
            "hot_phase": hot,
            "bytes_ratio_gather_over_fused":
                g["phases"][hot]["kv_bytes_touched"]
                / f["phases"][hot]["kv_bytes_touched"],
            "e2e_tok_s_wall_gather": g["e2e_tok_s_wall"],
            "e2e_tok_s_wall_fused": f["e2e_tok_s_wall"],
            "e2e_bytes_gather": g["kv_bytes_total"],
            "e2e_bytes_fused": f["kv_bytes_total"],
            "e2e_improves_modeled":
                f["kv_bytes_total"] < g["kv_bytes_total"],
        }
        print(f"[bench] phases headline {w}: {hot} bytes ratio "
              f"{summary[w]['bytes_ratio_gather_over_fused']:.2f}x, "
              f"e2e bytes {g['kv_bytes_total']} -> {f['kv_bytes_total']} "
              f"(modeled win: {summary[w]['e2e_improves_modeled']})")
    return {"fused_interpret_mode": interpret, "cells": cells,
            "summary": summary}


def build_fleet(roles, *, routing="load", affinity_weight=1.0,
                prompt_len=PROMPT_LEN, gen=GEN, slots=SLOTS,
                num_kv_blocks=0, prefix_sharing=False,
                prefill_chunk=PREFILL_CHUNK):
    """N virtual replicas on the 2-device group: one model + one set of
    weights, one engine (and KV pool) per role entry, one shared wall
    clock, a ``FleetRouter`` on top."""
    # window disabled: the fleet cells run 64-token prompts plus decode,
    # and reduced() clamps the arch to a 64-token window that would
    # reject the block-rounded paged pool
    cfg = get_config(ARCH).reduced().replace(sliding_window=0)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, policy="harmoeny"))
    mesh = make_host_mesh(data=1, model=MODEL_PAR)
    ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg, ParallelConfig(attn_chunk=min(512, prompt_len)),
                        batch=slots, seq_len=prompt_len,
                        mesh_shape=ms, mesh=mesh)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
    clock = WallClock()
    engines = [ServeEngine(
        model, params,
        engine_config_for(cfg, max_slots=slots, prompt_len=prompt_len,
                          max_new_tokens=gen, prefill_chunk=prefill_chunk,
                          skew_seed=1, paged=True, kv_block_size=KV_BLOCK,
                          num_kv_blocks=num_kv_blocks,
                          prefix_sharing=prefix_sharing, role=role),
        mesh=mesh, clock=clock) for role in roles]
    fleet = FleetRouter(engines, policy=routing,
                        affinity_weight=affinity_weight)
    fleet.warmup()
    return cfg, fleet


def _fleet_cell(rep, **extra):
    fl = rep["fleet"]
    agg, routing = fl["aggregate"], fl["routing"]
    return {
        **extra,
        "n_requests": agg["n_requests"],
        "ttft_p50_ms": agg["ttft"]["p50"] * 1e3,
        "ttft_p99_ms": agg["ttft"]["p99"] * 1e3,
        "tpot_p50_ms": agg["tpot"]["p50"] * 1e3,
        "tpot_p99_ms": agg["tpot"]["p99"] * 1e3,
        "e2e_p50_ms": agg["e2e"]["p50"] * 1e3,
        "queue_delay_p50_ms": agg["queue_delay"]["p50"] * 1e3,
        "tok_s": agg["throughput_tok_s"],
        "goodput_req_s": agg["goodput_req_s"],
        "prefix_hit_rate": agg["prefix_hit_rate"],
        "routed_per_replica": routing["per_replica"],
        "affinity_hit_rate": routing["affinity_hit_rate"],
        "affinity_hit_tokens": routing["affinity_hit_tokens"],
        "prefill_chunks_total": sum(r["prefill_chunks"]
                                    for r in rep["replica_reports"]),
        "preemptions": agg["preemptions"],
        "handoffs_moved": fl["handoffs"]["moved"],
        "handoff_mib": fl["handoffs"]["bytes"] / 2 ** 20,
        "recompiled_after_warmup": [
            bool(r.get("recompiled_after_warmup"))
            for r in rep["replica_reports"]],
    }


def fleet_compare():
    """Fleet serving: prefix-affinity routing and prefill/decode
    disaggregation vs their single-policy baselines.

    * **routing cells** — 2 unified replicas with prefix sharing on a
      THREE-group shared-prefix trace (three 56-token system prompts,
      8-token tails) under a block budget that fits about two cached
      prefixes plus active chains per replica.  ``load`` routing balances
      instantaneous queued+KV tokens and ignores cache state, so all
      three groups keep landing on both replicas and the LRU prefix cache
      thrashes — repeated cold 4-chunk prefills; ``prefix_affinity``
      probes each replica's radix index (LRU-neutral) and pins each group
      where its prefix is already resident, so each replica serves a
      stable subset of prefixes from cache and followers prefill only
      their 8-token tail.  Fewer prefill chunks is the deterministic
      work-saved signal; lower TTFT p50 is the headline.
    * **disaggregation cells** — a steady short-prompt/long-decode stream
      plus a mid-run burst of long-prompt prefill-only requests
      (``max_new_tokens=1``: the first token finishes them, so they never
      hand off), on 2 unified replicas vs 1 prefill-role + 1 decode-role
      replica (KV handoff).  Unified replicas interleave the burst's
      prefill chunks with in-flight decode steps, stalling every decode
      slot they share an engine with; the disaggregated decode replica
      never runs prompt prefills, so the burst cannot touch its decode
      cadence — lower TPOT p99 at equal device count is the headline.
    """
    cells = {"routing": [], "disaggregation": []}

    # -------------- routing: three prefix groups, 2 unified replicas ---
    # budget: a 56-token prefix caches as 7 blocks; 30 blocks hold two
    # prefixes plus active chains, NOT all three — cache-blind routing
    # thrashes, affinity partitions the groups across the replicas
    plen, tail_gen, prefix_len, n_per_group = 64, 8, 56, 8
    for routing in ("load", "round_robin", "prefix_affinity"):
        # weight 3: a 56-token cached prefix offsets ~168 tokens of load
        # (≈ 2.5 queued prompts), so a warm replica keeps its group even
        # while briefly busier — weight 1 lets one queued prompt push the
        # group onto a cold replica and duplicate its cache footprint
        cfg, fleet = build_fleet(["unified"] * 2, routing=routing,
                                 affinity_weight=3.0,
                                 prompt_len=plen, gen=tail_gen, slots=3,
                                 num_kv_blocks=30, prefix_sharing=True)
        groups = [poisson_requests(
            n_per_group, rate=6.0, vocab_size=cfg.vocab_size,
            prompt_len=plen, max_new_tokens=tail_gen, seed=20 + g,
            shared_prefix_len=prefix_len, rid_base=100 * g)
            for g in range(3)]
        rep = fleet.run(merge_requests(*groups))
        cell = _fleet_cell(rep, routing=routing, replicas=2,
                           prefix_groups=3, shared_prefix_len=prefix_len,
                           prompt_len=plen)
        cells["routing"].append(cell)
        print(f"[bench] fleet-routing {routing:15s} "
              f"ttft_p50={cell['ttft_p50_ms']:7.1f}ms "
              f"p99={cell['ttft_p99_ms']:7.1f}ms "
              f"prefill_chunks={cell['prefill_chunks_total']:3d} "
              f"hit={cell['prefix_hit_rate']} "
              f"affinity={cell['affinity_hit_rate']}")

    # ------------- disaggregation: steady decode + long-prompt burst ---
    plen, gen_steady = 64, 24
    def workload(cfg):
        steady = poisson_requests(
            8, rate=6.0, vocab_size=cfg.vocab_size, prompt_len=16,
            max_new_tokens=gen_steady, seed=30)
        # prefill-only burst: max_new_tokens=1 means the sampled first
        # token finishes each request on whatever engine prefilled it
        burst = [dataclasses.replace(r, arrival_time=0.25)
                 for r in poisson_requests(
                     8, rate=0.0, vocab_size=cfg.vocab_size,
                     prompt_len=plen, max_new_tokens=1, seed=31,
                     rid_base=500)]
        return merge_requests(steady, burst)

    for name, roles in (("unified", ["unified"] * 2),
                        ("disaggregated", ["prefill", "decode"])):
        cfg, fleet = build_fleet(roles, prompt_len=plen, gen=gen_steady,
                                 slots=6)
        rep = fleet.run(workload(cfg))
        cell = _fleet_cell(rep, mode=name, replicas=2,
                           steady_requests=8, burst_requests=8,
                           burst_prompt_len=plen)
        cells["disaggregation"].append(cell)
        print(f"[bench] fleet-disagg {name:14s} "
              f"tpot_p50={cell['tpot_p50_ms']:6.2f}ms "
              f"p99={cell['tpot_p99_ms']:7.2f}ms "
              f"ttft_p50={cell['ttft_p50_ms']:7.1f}ms "
              f"handoffs={cell['handoffs_moved']}")

    by_r = {c["routing"]: c for c in cells["routing"]}
    by_d = {c["mode"]: c for c in cells["disaggregation"]}
    headline = {
        "affinity_ttft_p50_ms": by_r["prefix_affinity"]["ttft_p50_ms"],
        "load_ttft_p50_ms": by_r["load"]["ttft_p50_ms"],
        "affinity_beats_load_ttft":
            by_r["prefix_affinity"]["ttft_p50_ms"]
            < by_r["load"]["ttft_p50_ms"],
        "affinity_prefill_chunks_saved":
            by_r["load"]["prefill_chunks_total"]
            - by_r["prefix_affinity"]["prefill_chunks_total"],
        "disagg_tpot_p99_ms": by_d["disaggregated"]["tpot_p99_ms"],
        "unified_tpot_p99_ms": by_d["unified"]["tpot_p99_ms"],
        "disagg_beats_unified_tpot_p99":
            by_d["disaggregated"]["tpot_p99_ms"]
            < by_d["unified"]["tpot_p99_ms"],
        "no_replica_recompiled": not any(
            any(c["recompiled_after_warmup"])
            for sec in cells.values() for c in sec),
    }
    print(f"[bench] fleet headline: affinity ttft_p50 "
          f"{headline['affinity_ttft_p50_ms']:.1f}ms vs load "
          f"{headline['load_ttft_p50_ms']:.1f}ms "
          f"(beats: {headline['affinity_beats_load_ttft']}, "
          f"chunks saved: {headline['affinity_prefill_chunks_saved']}); "
          f"disagg tpot_p99 {headline['disagg_tpot_p99_ms']:.2f}ms vs "
          f"unified {headline['unified_tpot_p99_ms']:.2f}ms "
          f"(beats: {headline['disagg_beats_unified_tpot_p99']})")
    return {"cells": cells, "headline": headline}


def _state_pool_cell(rep, **labels):
    cell = dict(labels)
    cell.update({
        "n_requests": rep["n_requests"],
        "ttft_p50_ms": rep["ttft"]["p50"] * 1e3,
        "ttft_p99_ms": rep["ttft"]["p99"] * 1e3,
        "tpot_p50_ms": rep["tpot"]["p50"] * 1e3,
        "throughput_tok_s": rep["throughput_tok_s"],
        "mean_occupancy": rep["mean_occupancy"],
        "preemptions": rep["preemptions"],
        "state_pool": rep["state_pool"],
        "recompiled_after_warmup": rep.get("recompiled_after_warmup"),
    })
    return cell


def state_pool_compare():
    """Sequence-state stores under long-context + bursty pressure.

    Four cells through the ``SequenceStateStore`` surface:

    * ``ssm_smooth`` / ``ssm_bursty`` — a pure-SSM (mamba2) engine on the
      slotted recurrent-state pool, the same request mix arriving as a
      smooth Poisson stream vs bursts at the same mean rate: bursts
      oversubscribe the fixed slot pool at one instant, so queueing shows
      up in TTFT p99 while the state pool itself stays fixed-size
      (``state_bytes_per_slot`` is length-independent — the SSM serving
      argument);
    * ``hybrid_long_context`` — a zamba2-style hybrid engine serving a
      long/short prompt mix near the pool ceiling: SSM leaves + attention
      slabs compose in one slot store;
    * ``ring_long_bursty`` — the paged transformer engine with prompts
      far beyond its sliding window, bursty arrivals: window-clamped
      layers serve as fixed-size ring-buffer chains (allocated whole at
      admission, never grown), so long contexts cost O(window) KV, not
      O(length).
    """
    cells = []

    def run(arch, label, reqs_fn, *, slots, prompt_len, gen, chunk,
            paged=False, **labels):
        cfg = get_config(arch).reduced()
        pcfg = ParallelConfig(attn_chunk=min(64, prompt_len))
        if arch == ARCH:
            # the MoE arch runs expert/model-parallel like every other cell
            mesh = make_host_mesh(data=1, model=MODEL_PAR)
            ms = MeshShape(tuple(zip(mesh.axis_names, mesh.devices.shape)))
            model = build_model(cfg, pcfg, batch=slots, seq_len=prompt_len,
                                mesh_shape=ms, mesh=mesh)
            with mesh:
                params = model.init(jax.random.PRNGKey(0))
        else:
            mesh = None
            model = build_model(cfg, pcfg, batch=slots, seq_len=prompt_len)
            params = model.init(jax.random.PRNGKey(0))
        ecfg = engine_config_for(cfg, max_slots=slots,
                                 prompt_len=prompt_len,
                                 max_new_tokens=gen, prefill_chunk=chunk,
                                 paged=paged, kv_block_size=KV_BLOCK)
        eng = ServeEngine(model, params, ecfg, mesh=mesh)
        eng.warmup()
        rep = eng.run(reqs_fn(cfg, ecfg))
        cell = _state_pool_cell(rep, arch=arch, workload=label,
                                paged=paged, **labels)
        cells.append(cell)
        sp = cell["state_pool"]
        print(f"[bench] state-pool {label:20s} kind={sp['kind']:5s} "
              f"ttft_p50={cell['ttft_p50_ms']:7.1f}ms "
              f"p99={cell['ttft_p99_ms']:7.1f}ms "
              f"occ={cell['mean_occupancy']:.2f} "
              f"preempt={cell['preemptions']}")
        return cell

    # --- SSM: smooth Poisson vs bursty at the same 8 req/s mean rate ---
    n, plen, gen = 12, 64, 8
    run("mamba2-2.7b", "ssm_smooth",
        lambda cfg, ecfg: poisson_requests(
            n, rate=8.0, vocab_size=cfg.vocab_size, prompt_len=plen,
            max_new_tokens=gen, seed=40),
        slots=3, prompt_len=plen, gen=gen, chunk=16, arrivals="poisson")
    run("mamba2-2.7b", "ssm_bursty",
        lambda cfg, ecfg: bursty_requests(
            n, vocab_size=cfg.vocab_size, prompt_len=plen,
            max_new_tokens=gen, burst_size=6, burst_gap=0.75, seed=40),
        slots=3, prompt_len=plen, gen=gen, chunk=16, arrivals="bursty")

    # --- hybrid: long/short prompt mix near the pool ceiling ---
    run("zamba2-7b", "hybrid_long_context",
        lambda cfg, ecfg: long_context_requests(
            8, vocab_size=cfg.vocab_size, max_seq_len=ecfg.max_seq_len,
            max_new_tokens=gen, rate=8.0, long_frac=0.5, short_len=16,
            seed=41),
        slots=3, prompt_len=96, gen=gen, chunk=16, arrivals="poisson")

    # --- paged ring: prompts ~2x beyond the 64-token sliding window ---
    ring_cell = run(ARCH, "ring_long_bursty",
                    lambda cfg, ecfg: bursty_requests(
                        8, vocab_size=cfg.vocab_size, prompt_len=120,
                        max_new_tokens=gen, burst_size=4, burst_gap=0.75,
                        seed=42,
                        prompt_len_range=(72, 120)),
                    slots=3, prompt_len=120, gen=gen, chunk=16,
                    paged=True, arrivals="bursty")

    by_label = {c["workload"]: c for c in cells}
    headline = {
        "ssm_smooth_ttft_p99_ms": by_label["ssm_smooth"]["ttft_p99_ms"],
        "ssm_bursty_ttft_p99_ms": by_label["ssm_bursty"]["ttft_p99_ms"],
        "bursty_pressure_visible":
            by_label["ssm_bursty"]["ttft_p99_ms"]
            > by_label["ssm_smooth"]["ttft_p99_ms"],
        "ssm_state_bytes_per_slot":
            by_label["ssm_smooth"]["state_pool"]["state_bytes_per_slot"],
        "ring_engaged": bool(ring_cell["state_pool"].get("window_ring")),
        "ring_tokens": ring_cell["state_pool"].get("ring_tokens"),
        "no_cell_recompiled": not any(c["recompiled_after_warmup"]
                                      for c in cells),
    }
    print(f"[bench] state-pool headline: bursty ttft_p99 "
          f"{headline['ssm_bursty_ttft_p99_ms']:.1f}ms vs smooth "
          f"{headline['ssm_smooth_ttft_p99_ms']:.1f}ms "
          f"(pressure: {headline['bursty_pressure_visible']}); "
          f"ring engaged: {headline['ring_engaged']} "
          f"(M={headline['ring_tokens']}); "
          f"recompiles: {not headline['no_cell_recompiled']}")
    return {"cells": cells, "headline": headline}


ONLY_SECTIONS = {"fleet": ("fleet", lambda: fleet_compare()),
                 "state_pool": ("state_pool",
                                lambda: state_pool_compare())}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    ap.add_argument("--only", default="",
                    choices=["", *ONLY_SECTIONS],
                    help="run a single section and merge it into an "
                         "existing --out file (fresh runs leave this "
                         "empty and produce the full file)")
    args = ap.parse_args()

    if args.only:
        key, fn = ONLY_SECTIONS[args.only]
        section = fn()
        out = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                out = json.load(f)
        out[key] = section
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[bench] merged {key} section -> "
              f"{os.path.abspath(args.out)}")
        return

    results = sweep()
    capacity, gains, more = capacity_compare()
    prefix_cells, reductions, faster = prefix_compare()
    spec_cells, spec_spt, spec_wins, spec_tokens_equal = \
        speculative_compare()
    skew = skew_compare()
    residency = residency_compare()
    fleet = fleet_compare()
    state_pool = state_pool_compare()
    decode_attn = decode_attention_microbench()
    phases = phases_breakdown()

    out = {
        "meta": {
            "bench": "serve_load", "arch": ARCH, "reduced": True,
            "devices": len(jax.devices()), "model_par": MODEL_PAR,
            "slots": SLOTS, "n_requests": N_REQ,
            "prompt_len": PROMPT_LEN, "gen": GEN,
            "prefill_chunk": PREFILL_CHUNK,
            "kv_block_size": KV_BLOCK,
            "pool": "paged",
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "jax": jax.__version__,
        },
        "results": results,
        "capacity": {
            "cells": capacity,
            "concurrency_gain": gains,
            "paged_more_concurrent": more,
        },
        "prefix": {
            "cells": prefix_cells,
            "ttft_p50_reduction_ms": reductions,
            "sharing_faster": faster,
        },
        "speculative": {
            "cells": spec_cells,
            "steps_per_committed_token": spec_spt,
            "speculation_wins": spec_wins,
            "token_counts_equal_across_k": spec_tokens_equal,
        },
        "skew": skew,
        "residency": residency,
        "fleet": fleet,
        "state_pool": state_pool,
        "decode_attention": decode_attn,
        "phases": phases,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[bench] wrote {os.path.abspath(args.out)} "
          f"({len(results)} sweep + {len(capacity)} capacity + "
          f"{len(prefix_cells)} prefix + {len(spec_cells)} speculative + "
          f"{len(skew['engine_cells'])}+{len(skew['modeled_cells'])} skew + "
          f"{len(residency['engine_cells'])}+"
          f"{len(residency['modeled_cells'])} residency + "
          f"{len(fleet['cells']['routing'])}+"
          f"{len(fleet['cells']['disaggregation'])} fleet + "
          f"{len(state_pool['cells'])} state-pool + "
          f"{len(decode_attn['cells'])} decode-attention + "
          f"{len(phases['cells'])} phase-breakdown cells)")


if __name__ == "__main__":
    main()
