"""Shared benchmark machinery: run the REAL scheduler over synthetic skewed
workloads (paper §5.1.2) at the paper's topology (G=8) and feed the v5e time
model. One function per paper figure lives in benchmarks/run.py."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import schedule
from repro.core.simulator import SimCosts, simulate_layer
from repro.core.topology import EPTopology, make_topology, static_opt_placement


@dataclasses.dataclass
class BenchSetup:
    arch: str = "switch128"
    n_ranks: int = 8            # the paper's 8-GPU DGX topology
    tokens_per_rank: int = 16384
    top_k: int = 1
    q: int = 0                  # 0 -> derive from Eq. 4 with the sim costs
    cf_pair: float = 2.0
    num_foreign_slots: int = 8

    def __post_init__(self):
        cfg = get_config(self.arch)
        self.cfg = cfg
        self.num_experts = cfg.moe.num_experts
        self.top_k = cfg.moe.num_experts_per_tok
        self.costs = SimCosts(
            d_model=cfg.d_model, d_ff=cfg.moe.d_ff_expert,
            n_matrices=3 if cfg.act == "swiglu" else 2)
        self.topo = make_topology(self.n_ranks, self.num_experts)
        if self.q == 0:
            # Eq. 4: the chunk must compute at least as long as the fetch
            fetch_s = (self.costs.expert_bytes * self.costs.fetch_penalty
                       / self.costs.hw.ici_bw)
            phi_eff = self.costs.hw.peak_flops * self.costs.mfu
            self.q = int(fetch_s * phi_eff / self.costs.unit_flops) + 1

    @property
    def c_pair(self) -> int:
        per = -(-self.tokens_per_rank * self.top_k // self.n_ranks)
        return int(self.cf_pair * per)


def skewed_counts(rng: np.random.Generator, setup: BenchSetup, alpha: float,
                  n_hot: int = 1, dataset: str = "skew") -> np.ndarray:
    """Per-(rank, expert) unit histogram for one batch.

    dataset: 'skew' (paper's alpha mechanism), 'random' (uniform router),
    'constant' (all tokens to the same experts), 'zipf' (real-corpus
    surrogate, Fig. 1 shape)."""
    G, E = setup.n_ranks, setup.topo.padded_experts
    U = setup.tokens_per_rank * setup.top_k
    if dataset == "constant":
        counts = np.zeros((G, E), np.int64)
        counts[:, :setup.top_k] = setup.tokens_per_rank
        return counts
    if dataset == "zipf":
        p = 1.0 / np.arange(1, setup.num_experts + 1) ** 1.2
    elif dataset == "random":
        p = np.ones(setup.num_experts)
    else:
        p = np.full(setup.num_experts, (1 - alpha) / max(setup.num_experts - n_hot, 1))
        p[:n_hot] = alpha / n_hot
    p = p / p.sum()
    counts = np.zeros((G, E), np.int64)
    for g in range(G):
        counts[g, :setup.num_experts] = rng.multinomial(U, p)
    return counts


_sched_cache: Dict = {}


def run_policy(counts: np.ndarray, setup: BenchSetup, policy: str):
    """Real (jitted) scheduler -> simulated layer metrics."""
    topo = setup.topo
    if policy == "static_opt":
        # ExFlow-like: placement optimized offline on a profile batch
        profile = counts.sum(axis=0)[:setup.num_experts]
        perm = static_opt_placement(profile.astype(np.float64), setup.n_ranks)
        topo = make_topology(setup.n_ranks, setup.num_experts, placement=perm)
        policy_eff = "round_robin"
    else:
        policy_eff = policy
    key = (id(setup.cfg), setup.n_ranks, policy_eff, setup.q, setup.c_pair,
           setup.num_foreign_slots,
           policy == "static_opt" and tuple(topo.slot_map.flatten()))
    fn = _sched_cache.get(key)
    if fn is None:
        topo_c = topo

        def _run(c):
            return schedule(c, topo_c, policy=policy_eff, q=setup.q,
                            c_pair=setup.c_pair,
                            num_foreign_slots=setup.num_foreign_slots)
        fn = jax.jit(_run)
        _sched_cache[key] = fn
    S, diag = fn(jnp.asarray(counts, jnp.int32))
    S = np.asarray(S)
    # dispatch drops: off-diagonal pair overflow beyond c_pair
    offdiag = S.sum(axis=1) * (1 - np.eye(topo.num_ranks, dtype=np.int64))
    drops = int(np.maximum(offdiag - setup.c_pair, 0).sum())
    metrics = simulate_layer(S, topo, setup.costs,
                             sched_iters=int(diag.iters), drops=drops)
    metrics["sched_iters"] = int(diag.iters)
    metrics["moved"] = int(diag.moved)
    return S, metrics


def model_tokens_per_s(layer_metrics: Dict[str, float], setup: BenchSetup,
                       include_attention: bool = True) -> float:
    """Scale per-MoE-layer time to full-model throughput (tokens/s)."""
    cfg = setup.cfg
    L = cfg.num_layers
    n_moe = (L - cfg.moe.first_dense_layers) // cfg.moe.moe_layer_period
    # non-MoE per-layer time: attention + dense FFN at the same batch
    tokens = setup.tokens_per_rank * setup.n_ranks
    dense_flops = tokens * 2 * (
        4 * setup.cfg.d_model * setup.cfg.num_heads * setup.cfg.resolved_head_dim)
    dense_s = dense_flops / (setup.costs.hw.peak_flops * setup.costs.mfu
                             * setup.n_ranks)
    total = n_moe * layer_metrics["layer_s"] + L * dense_s * include_attention
    return tokens / total
